"""Failure detection + deterministic restart protocol.

At 1000+ nodes, node loss is routine; the framework's contract is:

  1. every worker heartbeats (host process, one per node);
  2. the monitor declares a worker dead after ``timeout`` missed beats;
  3. the controller computes a restart plan: the survivor set, the new
     mesh shape (largest power-of-two DP degree that fits — see
     elastic.py), the checkpoint generation to restore, and the
     DataCursor step to resume from;
  4. workers restart, restore bit-exact state, and replay the data
     stream from the cursor — the loss curve continues as if the
     failure never happened (tested in tests/test_checkpoint_runtime.py
     via a simulated kill-restore-replay cycle).

This module is runnable logic (driven by the tests and by
launch/train.py's single-host simulation), not a daemon — the
cluster-manager integration point is the HeartbeatTable API.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class HeartbeatTable:
    timeout: float = 30.0
    _last: dict[str, float] = field(default_factory=dict)

    def beat(self, worker: str, now: float | None = None):
        self._last[worker] = time.monotonic() if now is None else now

    def dead_workers(self, now: float | None = None) -> list[str]:
        now = time.monotonic() if now is None else now
        return sorted(
            w for w, t in self._last.items() if now - t > self.timeout
        )

    def live_workers(self, now: float | None = None) -> list[str]:
        now = time.monotonic() if now is None else now
        return sorted(
            w for w, t in self._last.items() if now - t <= self.timeout
        )


@dataclass(frozen=True)
class RestartPlan:
    survivors: tuple[str, ...]
    mesh_shape: tuple[int, ...]
    restore_step: int | None
    data_cursor_step: int
    corpus_generation: int | None = None


def plan_restart(
    table: HeartbeatTable,
    chips_per_worker: int,
    model_parallel: int,
    latest_ckpt_step: int | None,
    steps_per_ckpt_interval: int = 0,
    corpus_generation: int | None = None,
    now: float | None = None,
) -> RestartPlan:
    """Shrink-to-fit plan: keep model parallelism fixed (a model shard
    set must be complete), drop data-parallel replicas to the largest
    power of two the survivors can host."""
    survivors = tuple(table.live_workers(now))
    chips = len(survivors) * chips_per_worker
    dp = max(1, chips // model_parallel)
    dp = 1 << (dp.bit_length() - 1)  # floor to power of two
    return RestartPlan(
        survivors=survivors,
        mesh_shape=(dp, model_parallel),
        restore_step=latest_ckpt_step,
        data_cursor_step=(latest_ckpt_step or 0),
        corpus_generation=corpus_generation,
    )
