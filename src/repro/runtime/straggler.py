"""Straggler detection: EWMA step-time outlier tracking.

Retrieval shards are equal-size by construction (pad_corpus), so a
persistent retrieval straggler is hardware, not skew — the mitigation
is shard migration (elastic.py: content-addressed shards move with a
manifest edit).  For training, the mitigations exposed are (a) flagging
for the cluster manager to swap the node and (b) micro-batch rebalance
hooks.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class StragglerDetector:
    alpha: float = 0.1  # EWMA coefficient
    threshold: float = 1.5  # flag if step_time > threshold × fleet EWMA
    min_samples: int = 5
    _ewma: dict[str, float] = field(default_factory=dict)
    _count: dict[str, int] = field(default_factory=dict)

    def observe(self, worker: str, step_time: float):
        prev = self._ewma.get(worker)
        self._ewma[worker] = (
            step_time if prev is None
            else (1 - self.alpha) * prev + self.alpha * step_time
        )
        self._count[worker] = self._count.get(worker, 0) + 1

    def fleet_ewma(self) -> float:
        vals = [v for w, v in self._ewma.items()
                if self._count[w] >= self.min_samples]
        return sum(vals) / len(vals) if vals else 0.0

    def stragglers(self) -> list[str]:
        fleet = self.fleet_ewma()
        if fleet == 0.0:
            return []
        return sorted(
            w for w, v in self._ewma.items()
            if self._count[w] >= self.min_samples and v > self.threshold * fleet
        )
