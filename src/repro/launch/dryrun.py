import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture × input shape ×
# mesh) cell and extract the roofline terms from the compiled artifact.
#
# MUST be run as a module entry point (``python -m repro.launch.dryrun``)
# or imported before anything touches jax — the XLA_FLAGS line above has
# to execute before jax locks the device count.  (Hence also: no module
# docstring — the os.environ lines above are deliberately the first two
# statements of the file, per the dry-run contract.)
#
# Per cell this prints/records:
# - ``compiled.memory_analysis()``  → bytes/device (proves it fits)
# - ``compiled.cost_analysis()``    → HLO FLOPs + HBM bytes
# - collective bytes, parsed from the post-SPMD HLO text: the summed
#   operand sizes of all-gather / all-reduce / reduce-scatter /
#   all-to-all / collective-permute ops (cost_analysis does not report
#   these).
#
# Results are dumped as JSON (one file per cell) for benchmarks/roofline.py.
# (No ``from __future__`` import: the XLA_FLAGS lines must be the first
# statements in the file, and __future__ imports may not follow them.)

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ARCHS, get as get_arch  # noqa: E402
from repro.configs import shapes as shp  # noqa: E402
from repro.launch import mesh as meshlib  # noqa: E402
from repro.launch import steps  # noqa: E402

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32"
                       r"|s16|u16|s8|u8|pred)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:[%\w.-]+ = )?"
    r"(\([^=]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[.\w-]*\(",
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes per collective kind over the HLO module.

    Uses the op *result* shape (for all-gather / all-to-all this equals
    the full exchanged payload; for all-reduce it equals the reduced
    tensor, the standard 2(n-1)/n ring cost is applied by the roofline
    model, not here).
    """
    out: dict[str, int] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        out[kind] = out.get(kind, 0) + b
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


# ---------------------------------------------------------------------------
# dry-run driver
# ---------------------------------------------------------------------------

def run_cell(arch_id: str, shape_id: str, multi_pod: bool,
             out_dir: str | None = None, verbose: bool = True) -> dict:
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = steps.build_cell(arch_id, shape_id, mesh)
    lowered = cell.fn.lower(*cell.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    colls = collective_bytes(hlo)

    rec = {
        "arch": arch_id,
        "shape": shape_id,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "kind": cell.meta.get("kind"),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", 0),
        },
        "collectives": colls,
    }
    if verbose:
        print(f"[{rec['mesh']}] {arch_id} × {shape_id}: "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s  "
              f"flops={rec['flops']:.3e}  "
              f"coll={colls['total_bytes']:.3e}B "
              f"({colls['counts']})", flush=True)
        print(f"    memory_analysis: args={rec['memory']['argument_bytes']:.3e} "
              f"temp={rec['memory']['temp_bytes']:.3e} "
              f"out={rec['memory']['output_bytes']:.3e}", flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch_id}__{shape_id}__{rec['mesh']}".replace("/", "_")
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id")
    ap.add_argument("--shape", default=None, help="single shape id")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-ragdb", action="store_true")
    args = ap.parse_args()

    if args.arch:
        shapes = ([args.shape] if args.shape else
                  list(shp.shapes_for_family(get_arch(args.arch).family)))
        cells = [(args.arch, s) for s in shapes]
    else:
        from repro.configs import cells as all_cells

        cells = all_cells()
        if args.skip_ragdb:
            cells = [c for c in cells if c[0] != "ragdb"]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}
    failures = []
    for arch_id, shape_id in cells:
        for multi_pod in meshes[args.mesh]:
            try:
                run_cell(arch_id, shape_id, multi_pod, args.out)
            except Exception as e:  # noqa: BLE001 — report, keep going
                failures.append((arch_id, shape_id, multi_pod, repr(e)))
                print(f"FAIL [{'2x16x16' if multi_pod else '16x16'}] "
                      f"{arch_id} × {shape_id}: {e}", flush=True)
                traceback.print_exc()
            finally:
                # 84 compiles of ≤30 B-param graphs in one process: drop
                # the executable caches or host RAM accumulates.
                jax.clear_caches()
                import gc

                gc.collect()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUN CELLS PASSED")


if __name__ == "__main__":
    main()
