"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init,
and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; 2×16×16 = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / single-host runs)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return jax.make_mesh(
        (n // model_parallel, model_parallel), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def make_shard_mesh(n_shards: int):
    """1-D ("shards",) mesh over the first ``n_shards`` devices — the
    retrieval index plane's distribution axis (index/sharded.py): each
    device owns a disjoint cluster subset and reranks it locally.

    Returns None when n_shards == 1 (nothing to distribute) or the host
    can't field that many devices — callers fall back to a logical
    per-shard loop on the default device with identical numerics.
    """
    if n_shards <= 1:
        return None
    import numpy as np

    devices = jax.devices()
    if len(devices) < n_shards:
        return None
    return jax.sharding.Mesh(np.array(devices[:n_shards]), ("shards",))


def dp_axes(mesh) -> tuple[str, ...]:
    """Data-parallel axes: pod (if present) + data."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out


def all_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)
