"""RAG serving driver: knowledge container + generation plane, fronted
by the concurrent serving runtime.

Loads (or builds) a knowledge container, instantiates the serving
runtime (micro-batching scheduler → generation-pinned snapshot →
QueryEngine — docs/ARCHITECTURE.md §7) and an LM, then serves requests:
every query is ``submit()``-ed individually and the scheduler coalesces
them into batched scoring dispatches; generation (pack → prefill →
decode) runs per request on the resolved retrievals.  Prints the
serving metrics snapshot (p50/p99, QPS, batch occupancy, cache hit
rate) at the end.

    PYTHONPATH=src python -m repro.launch.serve \
        --corpus /path/to/docs --max-batch 8 \
        --queries "what is INV-2024?" ...
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get as get_arch
from repro.core.ingest import KnowledgeBase
from repro.core.rag import RAGPipeline
from repro.models import transformer as T
from repro.obs import (
    SLOTargets,
    format_breakdown,
    trace as obs_trace,
    write_chrome_trace,
)
from repro.serving import RequestRejected, ServingRuntime


def _slo_from_args(args) -> SLOTargets | None:
    if args.slo_p99_ms is not None:
        return SLOTargets(p99_ms=args.slo_p99_ms)
    return None


def _print_health(runtime) -> None:
    import json

    h = runtime.health()
    print(f"health: {h['status']}")
    for reason in h["reasons"]:
        print(f"  - {reason}")
    print(json.dumps(h, indent=2, sort_keys=True, default=str))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--container", default=None, help=".ragdb to load")
    ap.add_argument("--corpus", default=None, help="directory to ingest")
    ap.add_argument("--save", default=None, help="save container here")
    ap.add_argument("--queries", nargs="+", required=True)
    ap.add_argument("--top-k", type=int, default=3)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--dim", type=int, default=4096)
    ap.add_argument("--max-batch", "--batch-size", dest="max_batch",
                    type=int, default=8,
                    help="scheduler flush cap (requests per dispatch)")
    ap.add_argument("--flush-deadline-ms", type=float, default=2.0,
                    help="micro-batch flush deadline (latency bound)")
    ap.add_argument("--scoring-path", default="auto",
                    choices=["auto", "map", "gemm", "kernel"],
                    help="auto = kernel on TPU, bit-stable map elsewhere")
    ap.add_argument("--use-kernel", action="store_true",
                    help="legacy alias for --scoring-path kernel")
    ap.add_argument("--index", default="flat",
                    choices=["flat", "ivf", "ivf-sharded"],
                    help="flat = full scan; ivf = clustered probe/rerank "
                    "(sublinear, exact HSF within the probed set); "
                    "ivf-sharded = the cluster plane partitioned across "
                    "the device mesh (--shards)")
    ap.add_argument("--nprobe", type=int, default=8,
                    help="clusters probed per query (index=ivf)")
    ap.add_argument("--guarantee", default="probe",
                    choices=["probe", "exact"],
                    help="exact = widen probes until top-k provably "
                    "matches the flat scan (index=ivf)")
    ap.add_argument("--shards", type=int, default=None,
                    help="cluster shards for index=ivf-sharded (default: "
                    "the jax device count; falls back to a logical "
                    "per-shard loop when devices are fewer)")
    ap.add_argument("--tenant-root", default=None, metavar="DIR",
                    help="serve multi-tenant: one container pool rooted "
                    "here (<DIR>/<tenant>.ragdb per tenant), lazy mounts "
                    "+ LRU eviction under --resident-budget "
                    "(docs/ARCHITECTURE.md §13); queries round-robin "
                    "over --tenants tenant ids")
    ap.add_argument("--tenants", type=int, default=2,
                    help="tenant count to drive in --tenant-root mode")
    ap.add_argument("--resident-budget", type=int, default=8,
                    help="max tenants mounted at once (LRU beyond this)")
    ap.add_argument("--quota-rate", type=float, default=None,
                    help="per-tenant admission quota: sustained "
                    "requests/s (token bucket; rejections surface as "
                    "RequestRejected)")
    ap.add_argument("--quota-burst", type=int, default=None,
                    help="per-tenant quota burst size (default: rate)")
    ap.add_argument("--metrics", action="store_true",
                    help="print the Prometheus exposition (serving "
                    "registry + global obs registry) and the engine's "
                    "index_stats() after the run")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="enable request tracing and write a Chrome "
                    "trace-event JSON (load in Perfetto / "
                    "chrome://tracing; inspect with "
                    "`python -m repro.obs FILE`)")
    ap.add_argument("--explain", action="store_true",
                    help="submit every query with explain=True and print "
                    "its EXPLAIN plan (probe set, widen rounds, bound "
                    "evidence, cache disposition, stage durations)")
    ap.add_argument("--slo-p99-ms", type=float, default=None,
                    help="p99 latency SLO target for --health (default "
                    "SLOTargets otherwise)")
    ap.add_argument("--health", action="store_true",
                    help="print the SLO health verdict "
                    "(runtime.health(): ok | degraded | critical with "
                    "reasons) after the run")
    args = ap.parse_args(argv)

    if args.trace:
        obs_trace.enable()

    if args.tenant_root:
        return _serve_multitenant(args)

    if args.container:
        kb = KnowledgeBase.load(args.container)
        print(f"loaded container: {kb.n_docs} docs")
    else:
        kb = KnowledgeBase(dim=args.dim)
    if args.corpus:
        stats = kb.sync(args.corpus)
        print(f"sync: +{stats.added} ~{stats.updated} -{stats.removed} "
              f"(skipped {stats.skipped}) in {stats.seconds:.2f}s")
    if args.save:
        kb.save(args.save)
        print(f"published container → {args.save}")

    runtime = ServingRuntime(
        kb,
        max_batch=max(1, args.max_batch),
        flush_deadline=args.flush_deadline_ms / 1e3,
        scoring_path="kernel" if args.use_kernel else args.scoring_path,
        index=args.index,
        nprobe=args.nprobe,
        guarantee=args.guarantee,
        slo=_slo_from_args(args),
        **({"n_shards": args.shards}
           if args.index == "ivf-sharded" and args.shards else {}),
    )
    arch = get_arch(args.arch)
    cfg = arch.smoke_config  # CPU host: reduced generator
    params = T.init(jax.random.PRNGKey(0), cfg)
    rag = RAGPipeline(kb, params, cfg, engine=runtime.engine)

    with runtime:
        # scope the throughput clock to serving, not model init
        runtime.metrics.reset()
        shard_note = ""
        if args.index == "ivf-sharded" and runtime.engine.ivf is not None:
            ivf = runtime.engine.ivf
            shard_note = (f", shards: {ivf.n_shards} "
                          f"{'mesh' if ivf.mesh is not None else 'logical'}")
        print(f"serving generation {runtime.generation} "
              f"(scoring path: {runtime.engine.scoring_path}{shard_note}, "
              f"flush ≤ {args.flush_deadline_ms:.1f} ms, "
              f"batch ≤ {args.max_batch})")
        t0 = time.perf_counter()
        futures = []
        for q in args.queries:
            try:
                futures.append((q, runtime.submit(
                    q, k=args.top_k, explain=args.explain)))
            except RequestRejected as exc:
                print(f"REJECTED {q!r}: {exc}")
        for q, fut in futures:
            served = fut.result()
            out = rag.generate(q, served.results, args.max_new_tokens)
            print(f"\nQ: {q}  [generation {served.generation}"
                  f"{', cached' if served.cached else ''}]")
            for r in out.retrieved:
                mark = "*" if r.boosted else " "
                print(f"  {mark} {r.doc_id:30s} score={r.score:.4f}")
            print(f"  generated token ids: {out.token_ids}")
            if args.explain and served.plan is not None:
                print(served.plan.render())
        dt = time.perf_counter() - t0
        if args.health:
            _print_health(runtime)
    print(f"\n{len(futures)} requests in {dt * 1e3:.1f} ms")
    print(f"serving metrics: {runtime.metrics.format()}")
    if args.metrics:
        stats = runtime.index_stats()
        print("index stats: " + ", ".join(
            f"{k}={v}" for k, v in stats.items()))
        print(runtime.render_metrics(), end="")
    if args.trace:
        spans = obs_trace.get().drain()
        n = write_chrome_trace(args.trace, spans)
        print(f"trace: {n} events → {args.trace}")
        print(format_breakdown(spans))
    return 0


def _serve_multitenant(args) -> int:
    """N tenants through one runtime: pool-mounted containers, queries
    round-robined over the tenant ids (retrieval plane only — per-tenant
    LM generation composes the same way the single-tenant path does)."""
    from repro.tenancy import ContainerPool, TenantQuotas

    pool = ContainerPool(
        args.tenant_root,
        kb_kwargs={"dim": args.dim},
        max_resident=max(1, args.resident_budget),
        scoring_path="kernel" if args.use_kernel else args.scoring_path,
        index=args.index,
        nprobe=args.nprobe,
        guarantee=args.guarantee,
        **({"n_shards": args.shards}
           if args.index == "ivf-sharded" and args.shards else {}),
    )
    quotas = None
    if args.quota_rate:
        quotas = TenantQuotas(default_rate=args.quota_rate,
                              default_burst=args.quota_burst)
    runtime = ServingRuntime(
        pool=pool, quotas=quotas,
        max_batch=max(1, args.max_batch),
        flush_deadline=args.flush_deadline_ms / 1e3,
        slo=_slo_from_args(args),
    )
    names = [f"tenant{i:02d}" for i in range(max(1, args.tenants))]
    with runtime:
        if args.corpus:
            for name in names:
                with runtime.tenant_writer(name) as kb:
                    stats = kb.sync(args.corpus)
                runtime.publish(tenant=name, durable=True)
                print(f"[{name}] sync: +{stats.added} ~{stats.updated} "
                      f"-{stats.removed} → durable publish")
        print(f"serving {len(names)} tenants "
              f"(resident budget {pool.max_resident}, "
              f"flush ≤ {args.flush_deadline_ms:.1f} ms, "
              f"batch ≤ {args.max_batch})")
        t0 = time.perf_counter()
        futures = []
        for i, q in enumerate(args.queries):
            name = names[i % len(names)]
            try:
                futures.append(
                    (name, q, runtime.submit(q, k=args.top_k, tenant=name,
                                             explain=args.explain)))
            except RequestRejected as exc:
                print(f"REJECTED [{exc.tenant}] {q!r}: {exc}")
        for name, q, fut in futures:
            served = fut.result()
            print(f"\n[{name}] Q: {q}  [generation {served.generation}"
                  f"{', cached' if served.cached else ''}]")
            for r in served.results:
                mark = "*" if r.boosted else " "
                print(f"  {mark} {r.doc_id:30s} score={r.score:.4f}")
            if args.explain and served.plan is not None:
                print(served.plan.render())
        dt = time.perf_counter() - t0
        print(f"\n{len(futures)} requests in {dt * 1e3:.1f} ms")
        print(f"serving metrics: {runtime.metrics.format()}")
        for name, m in sorted(runtime.tenant_metrics().items()):
            print(f"  [{name}] qps={m['qps']:.0f} "
                  f"p50={m['latency_p50_ms']:.2f}ms "
                  f"p99={m['latency_p99_ms']:.2f}ms "
                  f"rejected={m['rejected']}")
        ps = runtime.pool_stats()
        print(f"pool: {ps['resident']}/{ps['max_resident']} resident, "
              f"{ps['resident_bytes']} bytes, pinned={ps['pinned']}")
        res = runtime.resources()
        print(f"ledger: {res['resident_bytes']} resident bytes "
              f"({res['device_bytes']} device) across "
              f"{len(res['tenants'])} tenants")
        if args.health:
            _print_health(runtime)
        if args.metrics:
            print(runtime.render_metrics(), end="")
    pool.drain()  # durably publish + unmount everything on the way out
    if args.trace:
        spans = obs_trace.get().drain()
        n = write_chrome_trace(args.trace, spans)
        print(f"trace: {n} events → {args.trace}")
        print(format_breakdown(spans))
    return 0


if __name__ == "__main__":
    main()
