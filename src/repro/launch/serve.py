"""RAG serving driver: knowledge container + generation plane.

Loads (or builds) a knowledge container, instantiates the retrieval
tier and an LM, and serves batched requests: batched retrieve (one
QueryEngine dispatch per request batch) → pack → prefill → decode,
with per-batch timing split into retrieval vs generation.

    PYTHONPATH=src python -m repro.launch.serve \
        --corpus /path/to/docs --batch-size 8 \
        --queries "what is INV-2024?" ...
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get as get_arch
from repro.core.ingest import KnowledgeBase
from repro.core.rag import RAGPipeline
from repro.models import transformer as T


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--container", default=None, help=".ragdb to load")
    ap.add_argument("--corpus", default=None, help="directory to ingest")
    ap.add_argument("--save", default=None, help="save container here")
    ap.add_argument("--queries", nargs="+", required=True)
    ap.add_argument("--top-k", type=int, default=3)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--dim", type=int, default=4096)
    ap.add_argument("--batch-size", type=int, default=8,
                    help="requests per retrieval dispatch")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route HSF scoring through the Pallas kernel")
    args = ap.parse_args(argv)

    if args.container:
        kb = KnowledgeBase.load(args.container)
        print(f"loaded container: {kb.n_docs} docs")
    else:
        kb = KnowledgeBase(dim=args.dim)
    if args.corpus:
        stats = kb.sync(args.corpus)
        print(f"sync: +{stats.added} ~{stats.updated} -{stats.removed} "
              f"(skipped {stats.skipped}) in {stats.seconds:.2f}s")
    if args.save:
        kb.save(args.save)
        print(f"published container → {args.save}")

    arch = get_arch(args.arch)
    cfg = arch.smoke_config  # CPU host: reduced generator
    params = T.init(jax.random.PRNGKey(0), cfg)
    rag = RAGPipeline(kb, params, cfg, use_kernel=args.use_kernel)

    queries = args.queries
    batch_size = max(1, args.batch_size)
    for start in range(0, len(queries), batch_size):
        batch = queries[start: start + batch_size]
        t0 = time.perf_counter()
        retrieved = rag.engine.query_batch(batch, k=args.top_k)
        t_retrieve = time.perf_counter() - t0
        outs = [
            rag.generate(q, res, args.max_new_tokens)
            for q, res in zip(batch, retrieved)
        ]
        t_batch = time.perf_counter() - t0
        print(f"\nbatch [{start}:{start + len(batch)}]: "
              f"retrieve {t_retrieve * 1e3:.1f} ms "
              f"({t_retrieve / len(batch) * 1e3:.2f} ms/q), "
              f"total {t_batch * 1e3:.1f} ms")
        for q, out in zip(batch, outs):
            print(f"Q: {q}")
            for r in out.retrieved:
                mark = "*" if r.boosted else " "
                print(f"  {mark} {r.doc_id:30s} score={r.score:.4f}")
            print(f"  generated token ids: {out.token_ids}")
    hits = rag.engine.cache_stats()
    print(f"\nquery cache: {hits['hits']} hits / "
          f"{hits['hits'] + hits['misses']} lookups")
    return 0


if __name__ == "__main__":
    main()
