"""Distributed step builders: one jitted, fully-sharding-annotated step
function per (architecture family × shape kind).

This is the layer the dry-run lowers: ``build_cell(arch, shape, mesh)``
returns (jitted step, abstract args) such that
``fn.lower(*args).compile()`` proves the whole distribution config —
param/optimizer sharding, input sharding, KV-cache sharding, MoE
dispatch locality, embedding-table psum lookups — is coherent.

Sharding scheme (docs/ARCHITECTURE.md §6):
- params: FSDP over 'data' × TP over 'model' per matrix (rules below);
  optimizer m/v mirror params (ZeRO via specs).
- LM train: grad-accumulation scan over microbatches (per-device live
  batch = 1 sequence), remat inside the layer scan.
- decode: KV cache sharded batch→data when divisible, else seq→data
  (long_500k); heads→model when divisible, else head_dim→model.
- MoE dispatch + embedding lookups: partial-manual shard_map (manual
  over the token/row axis, auto TP elsewhere).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get as get_arch
from repro.configs import shapes as shp
from repro.launch import mesh as meshlib
from repro.models import transformer as T
from repro.models import moe as moe_mod
from repro.models.gnn import mace as mace_mod
from repro.models.recsys import autoint as autoint_mod
from repro.models.recsys import base as rec_base
from repro.models.recsys import deepfm as deepfm_mod
from repro.models.recsys import dlrm as dlrm_mod
from repro.models.recsys import embedding as emb_mod
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine

RECSYS_MODULES = {
    "dlrm-rm2": dlrm_mod, "dlrm-mlperf": dlrm_mod,
    "deepfm": deepfm_mod, "autoint": autoint_mod,
    "dlrm-rm2-smoke": dlrm_mod, "dlrm-mlperf-smoke": dlrm_mod,
    "deepfm-smoke": deepfm_mod, "autoint-smoke": autoint_mod,
}


# ==========================================================================
# parameter sharding rules
# ==========================================================================

_COL_SHARDED = {"w_q", "w_k", "w_v", "w_gate", "w_up", "lm_head"}  # [in, out·tp]
_ROW_SHARDED = {"w_o", "w_down"}  # [in·tp, out]
_MLA_LORA = {"w_dkv", "w_kr"}  # [d, small]
_MLA_UP = {"w_uk", "w_uv"}  # [R, H·d] — no fsdp (R small)


def _path_keys(path) -> list[str]:
    return [str(p.key) if hasattr(p, "key") else str(p.idx) for p in path]


def lm_param_spec(path, leaf, fsdp: str | None, tp: str | None):
    keys = _path_keys(path)
    name = keys[-1]
    in_scan = "scan" in keys
    # MoE expert tensors are rank 3 ([E, ·, ·]), +1 when scan-stacked;
    # dense MLP weights are rank 2 (+1) — rank alone disambiguates only
    # together with the scan flag.
    moe_leaf = (
        name in {"w_gate", "w_up", "w_down"}
        and "shared" not in keys
        and leaf.ndim == (4 if in_scan else 3)
    )

    def wrap(*spec):
        return P(*(((None,) if in_scan else ()) + spec))

    if name == "embed":
        return P(tp, None)
    if name == "lm_head":
        return P(None, tp)
    if name == "router":
        return wrap(fsdp, None)
    if moe_leaf:
        if name == "w_down":  # [E, F, D]
            return wrap(None, tp, fsdp)
        return wrap(None, fsdp, tp)  # [E, D, F]
    if name in _COL_SHARDED:
        return wrap(fsdp, tp)
    if name in _ROW_SHARDED:
        return wrap(tp, fsdp)
    if name in _MLA_LORA:
        return wrap(fsdp, None)
    if name in _MLA_UP:
        return wrap(None, tp)
    return P()  # norms, biases, scalars


def lm_param_specs(params_shape, mesh, serving: bool = False):
    """``serving=True`` drops FSDP: weights shard over 'model' only
    (replicated over data/pod).  Decode reads every weight once per
    generated token — FSDP would all-gather the whole model each step
    (measured: 0.8–2.5 GB/step, the dominant decode collective), while
    TP-only serving leaves only the activation psums on the wire."""
    fsdp = None if serving else ("data" if "data" in mesh.axis_names else None)
    tp = "model" if "model" in mesh.axis_names else None
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [lm_param_spec(p, l, fsdp, tp) for p, l in flat]
    )


def opt_state_specs(param_specs):
    return {
        "m": param_specs,
        "v": param_specs,
        "step": P(),
    }


def lm_cache_spec(path, leaf, mesh):
    """KV-cache leaf specs (see module docstring for the rule)."""
    keys = _path_keys(path)
    in_scan = "scan" in keys
    name = keys[-1]
    dpn = meshlib.dp_size(mesh)
    dp = meshlib.dp_axes(mesh)
    tpn = mesh.shape["model"] if "model" in mesh.axis_names else 1
    tp = "model" if "model" in mesh.axis_names else None
    shape = leaf.shape[1:] if in_scan else leaf.shape

    def wrap(*spec):
        return P(*(((None,) if in_scan else ()) + spec))

    if name in ("k", "v"):  # [B, Hkv, S, hd]
        b, h, s, d = shape
        b_sh = dp if dpn > 1 and b % dpn == 0 else None
        h_sh = tp if tpn > 1 and h % tpn == 0 else None
        s_sh = dp if b_sh is None and s % dpn == 0 else None
        d_sh = tp if h_sh is None and d % tpn == 0 else None
        return wrap(b_sh, h_sh, s_sh, d_sh)
    if name == "c_kv":  # [B, S, R]
        b, s, r = shape
        b_sh = dp if dpn > 1 and b % dpn == 0 else None
        s_sh = dp if b_sh is None and s % dpn == 0 else None
        r_sh = tp if tpn > 1 and r % tpn == 0 else None
        return wrap(b_sh, s_sh, r_sh)
    if name == "k_rope":  # [B, 1, S, rope]
        b, _, s, r = shape
        b_sh = dp if dpn > 1 and b % dpn == 0 else None
        s_sh = dp if b_sh is None and s % dpn == 0 else None
        return wrap(b_sh, None, s_sh, None)
    raise ValueError(name)


def lm_cache_specs(cache_shape, mesh):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [lm_cache_spec(p, l, mesh) for p, l in flat]
    )


def _shardings(mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ==========================================================================
# LM steps
# ==========================================================================

@dataclass(frozen=True)
class Cell:
    """A fully-assembled dry-run cell: jit fn + abstract args."""
    arch_id: str
    shape_id: str
    fn: object  # jitted callable
    args: tuple  # ShapeDtypeStructs (or concrete arrays in tests)
    meta: dict


def kv_repeat_for(cfg: T.LMConfig, mesh) -> int:
    """KV replication factor giving clean head sharding, if one exists.

    Requires q heads to divide TP (else attention is head-misaligned
    regardless — e.g. llama3.2's 24 q heads on TP=16, noted in the
    generated EXPERIMENTS.md report) and the replicated KV head count
    to divide q heads.
    """
    tp = mesh.shape.get("model", 1)
    if cfg.mla is not None or tp <= 1:
        return 1
    if cfg.n_heads % tp != 0 or cfg.n_kv_heads % tp == 0:
        return 1
    import math

    r = tp // math.gcd(cfg.n_kv_heads, tp)
    eff = cfg.n_kv_heads * r
    if eff % tp == 0 and cfg.n_heads % eff == 0:
        return r
    return 1


def _moe_token_axes(cfg, mesh, n_tokens: int) -> tuple[str, ...]:
    if cfg.moe is None:
        return ()
    dp = meshlib.dp_axes(mesh)
    return dp if dp and n_tokens % meshlib.dp_size(mesh) == 0 else ()



def _run_in_ctx(cfg, mesh, token_axes, traced):
    """Trace ``traced`` under the activation-sharding context (+ the MoE
    dispatch context when the token count shards)."""
    with T.act_sharding_ctx(mesh, meshlib.dp_axes(mesh)):
        if token_axes:
            with moe_mod.sharding_ctx(mesh, token_axes):
                return traced()
        return traced()


def make_lm_train_step(cfg: T.LMConfig, mesh, n_micro: int,
                       adamw: AdamWConfig | None = None,
                       backend: str = "xla",
                       bf16_params: bool = False):
    """``bf16_params=True`` (beyond-paper §Perf): the working parameter
    copy is bf16 — every FSDP weight all-gather and weight read moves
    half the bytes — while the optimizer keeps an f32 master copy in
    opt_state["master"] (updates applied in f32, recast to bf16)."""
    adamw = adamw or AdamWConfig()
    dp = meshlib.dp_axes(mesh)

    def step_fn(params, opt_state, tokens, targets):
        # tokens/targets: [n_micro, micro_batch, seq]
        micro_tokens = tokens.shape[1] * tokens.shape[2]
        token_axes = _moe_token_axes(cfg, mesh, micro_tokens)

        def traced():
            def micro_step(acc, xs):
                tk, tg = xs
                loss, g = jax.value_and_grad(T.lm_loss)(
                    params, tk, tg, cfg, backend
                )
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g
                )
                return acc, loss

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, losses = jax.lax.scan(
                micro_step, zero, (tokens, targets),
                unroll=True if T.COST_EXACT_UNROLL else 1,
            )
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            lr = warmup_cosine(opt_state["step"], adamw.lr, 100, 10000)
            if bf16_params:
                opt_inner = {"m": opt_state["m"], "v": opt_state["v"],
                             "step": opt_state["step"]}
                new_master, new_inner = adamw_update(
                    grads, opt_inner, opt_state["master"], adamw, lr
                )
                new_params = jax.tree.map(
                    lambda mp, p: mp.astype(p.dtype), new_master, params
                )
                new_opt = {**new_inner, "master": new_master}
            else:
                new_params, new_opt = adamw_update(grads, opt_state, params,
                                                   adamw, lr)
            return new_params, new_opt, losses.mean()

        return _run_in_ctx(cfg, mesh, token_axes, traced)

    return step_fn


def build_lm_train_cell(arch_id, cfg: T.LMConfig, spec: shp.ShapeSpec, mesh,
                        per_device_batch: int = 1,
                        optimized: bool = True) -> Cell:
    m = spec.meta
    batch, seq = m["batch"], m["seq"]
    if optimized:
        cfg = replace(cfg, kv_repeat=kv_repeat_for(cfg, mesh))
    dpn = meshlib.dp_size(mesh)
    micro = min(batch, dpn * per_device_batch)
    n_micro = batch // micro
    dp = meshlib.dp_axes(mesh)

    master_shape = jax.eval_shape(lambda: T.init(jax.random.PRNGKey(0), cfg))
    p_specs = lm_param_specs(master_shape, mesh)
    if optimized:
        params_shape = jax.tree.map(_bf16_cast_shape, master_shape)
        opt_shape = {**jax.eval_shape(adamw_init, master_shape),
                     "master": master_shape}
        o_specs = {**opt_state_specs(p_specs), "master": p_specs}
    else:
        params_shape = master_shape
        opt_shape = jax.eval_shape(adamw_init, master_shape)
        o_specs = opt_state_specs(p_specs)
    tok_spec = P(None, dp, None)

    step = make_lm_train_step(cfg, mesh, n_micro, bf16_params=optimized)
    fn = jax.jit(
        step,
        in_shardings=(
            _shardings(mesh, p_specs), _shardings(mesh, o_specs),
            NamedSharding(mesh, tok_spec), NamedSharding(mesh, tok_spec),
        ),
        out_shardings=(
            _shardings(mesh, p_specs), _shardings(mesh, o_specs), None
        ),
        donate_argnums=(0, 1),
    )
    tok = jax.ShapeDtypeStruct((n_micro, micro, seq), jnp.int32)
    return Cell(arch_id, spec.shape_id, fn,
                (params_shape, opt_shape, tok, tok),
                {"n_micro": n_micro, "micro": micro, "kind": "lm_train"})


def make_lm_prefill_step(cfg: T.LMConfig, mesh, max_len: int,
                         backend: str = "xla"):
    def step_fn(params, tokens):
        token_axes = _moe_token_axes(
            cfg, mesh, tokens.shape[0] * tokens.shape[1]
        )

        def traced():
            logits, caches, lengths = T.prefill(params, tokens, cfg, max_len,
                                                backend)
            return logits[:, -1], caches, lengths

        return _run_in_ctx(cfg, mesh, token_axes, traced)

    return step_fn


def _bf16_cast_shape(l):
    """bf16 working-copy dtype for a param leaf.  MoE expert tensors
    (rank ≥ 3) stay f32: bf16 operands inside the partial-manual MoE
    shard_map trip an XLA spmd-partitioner CHECK ("Invalid binary
    instruction opcode copy", xla bug) — worked around by exempting
    them; routers/dense weights still benefit."""
    if l.dtype == jnp.float32 and l.ndim < 3:
        return jax.ShapeDtypeStruct(l.shape, jnp.bfloat16)
    return l


def _serving_params_shape(cfg, optimized):
    """Serving holds bf16 weights (no optimizer master copy on the
    serving fleet) when optimized; f32 for the paper-faithful baseline."""
    shape = jax.eval_shape(lambda: T.init(jax.random.PRNGKey(0), cfg))
    if not optimized:
        return shape
    return jax.tree.map(_bf16_cast_shape, shape)


def build_lm_prefill_cell(arch_id, cfg, spec, mesh,
                          optimized: bool = True) -> Cell:
    m = spec.meta
    batch, seq = m["batch"], m["seq"]
    dp = meshlib.dp_axes(mesh)
    if optimized:
        cfg = replace(cfg, kv_repeat=kv_repeat_for(cfg, mesh))
    params_shape = _serving_params_shape(cfg, optimized)
    p_specs = lm_param_specs(params_shape, mesh, serving=optimized)
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    cache_shape = jax.eval_shape(
        lambda: T.init_cache(cfg, batch, seq), )
    c_specs = lm_cache_specs(cache_shape, mesh)

    step = make_lm_prefill_step(cfg, mesh, seq)
    fn = jax.jit(
        step,
        in_shardings=(_shardings(mesh, p_specs),
                      NamedSharding(mesh, P(dp, None))),
        out_shardings=(None, _shardings(mesh, c_specs), None),
    )
    return Cell(arch_id, spec.shape_id, fn, (params_shape, tok),
                {"kind": "lm_prefill"})


def make_lm_decode_step(cfg: T.LMConfig, mesh, backend: str = "xla"):
    def step_fn(params, caches, tokens, lengths):
        token_axes = _moe_token_axes(cfg, mesh, tokens.shape[0])

        def traced():
            return T.decode_step(params, caches, tokens, lengths, cfg,
                                 backend)

        return _run_in_ctx(cfg, mesh, token_axes, traced)

    return step_fn


def build_lm_decode_cell(arch_id, cfg, spec, mesh,
                         optimized: bool = True) -> Cell:
    m = spec.meta
    batch, max_len = m["batch"], m["seq"]
    dpn = meshlib.dp_size(mesh)
    dp = meshlib.dp_axes(mesh) if batch % dpn == 0 and batch >= dpn else ()
    if optimized:
        cfg = replace(cfg, kv_repeat=kv_repeat_for(cfg, mesh))
    params_shape = _serving_params_shape(cfg, optimized)
    p_specs = lm_param_specs(params_shape, mesh, serving=optimized)
    cache_shape = jax.eval_shape(lambda: T.init_cache(cfg, batch, max_len))
    c_specs = lm_cache_specs(cache_shape, mesh)

    step = make_lm_decode_step(cfg, mesh)
    fn = jax.jit(
        step,
        in_shardings=(
            _shardings(mesh, p_specs), _shardings(mesh, c_specs),
            NamedSharding(mesh, P(dp or None, None)),
            NamedSharding(mesh, P(dp or None)),
        ),
        out_shardings=(None, _shardings(mesh, c_specs)),
        donate_argnums=(1,),
    )
    tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    lens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return Cell(arch_id, spec.shape_id, fn,
                (params_shape, cache_shape, tok, lens),
                {"kind": "lm_decode", "max_len": max_len})


# ==========================================================================
# GNN steps
# ==========================================================================

def gnn_param_specs(params_shape):
    # MACE params are small (≤ d_hidden² · few): replicate everything.
    return jax.tree.map(lambda _: P(), params_shape)


def make_gnn_train_step(cfg, mesh, kind: str, adamw: AdamWConfig | None = None):
    adamw = adamw or AdamWConfig()

    def loss_fn(params, batch):
        node_logits, energies = mace_mod.forward(
            params, batch["node_feats"], batch["positions"],
            batch["senders"], batch["receivers"], cfg,
            edge_mask=batch.get("edge_mask"),
            graph_ids=batch.get("graph_ids"),
            n_graphs=batch.get("n_graphs_static", 1),
        )
        if kind == "gnn_train_batched":
            return jnp.mean(
                jnp.square(energies - batch["energy_targets"])
            )
        logz = jax.scipy.special.logsumexp(node_logits, axis=-1)
        gold = jnp.take_along_axis(
            node_logits, batch["labels"][:, None].astype(jnp.int32), axis=-1
        )[:, 0]
        ce = logz - gold
        # padded node slots (and, for sampled training, non-seed nodes)
        # carry zero loss weight
        w = batch["node_mask"]
        if kind == "gnn_train_sampled":
            w = w * batch["seed_mask"]
        return jnp.sum(ce * w) / jnp.maximum(jnp.sum(w), 1.0)

    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        lr = warmup_cosine(opt_state["step"], adamw.lr, 100, 10000)
        new_params, new_opt = adamw_update(grads, opt_state, params, adamw, lr)
        return new_params, new_opt, loss

    return step_fn


def build_gnn_cell(arch_id, cfg, spec: shp.ShapeSpec, mesh) -> Cell:
    m = spec.meta
    cfg = replace(cfg, d_feat=m["d_feat"])
    shard = meshlib.all_axes(mesh)
    params_shape = jax.eval_shape(lambda: mace_mod.init(jax.random.PRNGKey(0),
                                                        cfg))
    p_specs = gnn_param_specs(params_shape)
    opt_shape = jax.eval_shape(adamw_init, params_shape)

    inputs = shp.input_specs(cfg, spec)
    in_sh = {
        "node_feats": P(shard, None), "positions": P(shard, None),
        "senders": P(shard), "receivers": P(shard), "labels": P(shard),
        "edge_mask": P(shard), "node_mask": P(shard),
    }
    if spec.kind == "gnn_train_sampled":
        in_sh["seed_mask"] = P(shard)
    if spec.kind == "gnn_train_batched":
        in_sh["graph_ids"] = P(shard)
        in_sh["energy_targets"] = P(None)

    step = make_gnn_train_step(cfg, mesh, spec.kind)

    def step_with_static(params, opt_state, batch):
        batch = dict(batch)
        batch["n_graphs_static"] = m["n_graphs"]
        return step(params, opt_state, batch)

    fn = jax.jit(
        step_with_static,
        in_shardings=(
            _shardings(mesh, p_specs),
            _shardings(mesh, opt_state_specs(p_specs)),
            _shardings(mesh, in_sh),
        ),
        out_shardings=(
            _shardings(mesh, p_specs),
            _shardings(mesh, opt_state_specs(p_specs)), None,
        ),
        donate_argnums=(0, 1),
    )
    return Cell(arch_id, spec.shape_id, fn, (params_shape, opt_shape, inputs),
                {"kind": spec.kind})


# ==========================================================================
# recsys steps
# ==========================================================================

def recsys_param_specs(params_shape, mesh):
    tp = "model" if "model" in mesh.axis_names else None

    def spec(path, leaf):
        keys = _path_keys(path)
        if keys[-1] in ("table", "first_order"):
            return P(tp) if leaf.ndim == 1 else P(tp, None)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat]
    )


def make_recsys_step(arch_id, cfg, mesh, kind: str,
                     adamw: AdamWConfig | None = None,
                     rowwise_tables: bool = True):
    """``rowwise_tables=True`` (beyond-paper §Perf): embedding tables
    update with row-wise Adagrad (one f32 scalar per row) while the
    dense towers stay on AdamW — the FBGEMM/DLRM production split,
    cutting table optimizer state 2·dim× (256× at dim 128)."""
    from repro.optim.rowwise import (RowwiseAdagradConfig, rowwise_update,
                                     split_tree)

    mod = RECSYS_MODULES[cfg.name if cfg.name in RECSYS_MODULES else arch_id]
    adamw = adamw or AdamWConfig(weight_decay=0.0)
    row_cfg = RowwiseAdagradConfig()

    def fwd(params, batch):
        with emb_mod.sharding_ctx(mesh, "model"):
            return mod.forward(params, batch.get("dense"),
                               batch["sparse_idx"], cfg)

    if kind == "recsys_serve":
        return fwd

    if kind == "recsys_retrieval":
        def retrieve(params, batch):
            with emb_mod.sharding_ctx(mesh, "model"):
                scores = mod.retrieval_scores(
                    params, batch["query"], batch["candidate_ids"], cfg
                )
            n_real = batch.get("n_real_candidates", scores.shape[0])
            idx = jnp.arange(scores.shape[0])
            scores = jnp.where(idx < n_real, scores, -jnp.inf)
            return jax.lax.top_k(scores, 16)

        return retrieve

    def loss_fn(params, batch):
        return rec_base.bce_with_logits(fwd(params, batch), batch["labels"])

    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        lr = warmup_cosine(opt_state["step"], adamw.lr, 100, 10000)
        if rowwise_tables:
            g_tab, g_dense = split_tree(grads)
            p_tab, p_dense = split_tree(params)
            new_dense, new_inner = adamw_update(
                g_dense, {"m": opt_state["m"], "v": opt_state["v"],
                          "step": opt_state["step"]},
                p_dense, adamw, lr,
            )
            new_tab = {}
            new_g2 = {}
            for k in p_tab:
                t2 = p_tab[k] if p_tab[k].ndim == 2 else p_tab[k][:, None]
                g2 = g_tab[k] if g_tab[k].ndim == 2 else g_tab[k][:, None]
                nt, ns = rowwise_update(
                    g2, {"g2": opt_state["g2"][k]}, t2, row_cfg
                )
                new_tab[k] = nt if p_tab[k].ndim == 2 else nt[:, 0]
                new_g2[k] = ns["g2"]
            new_params = {**new_dense, **new_tab}
            new_opt = {**new_inner, "g2": new_g2}
        else:
            new_params, new_opt = adamw_update(grads, opt_state, params,
                                               adamw, lr)
        return new_params, new_opt, loss

    return step_fn


def build_recsys_cell(arch_id, cfg, spec: shp.ShapeSpec, mesh) -> Cell:
    m = spec.meta
    dp = meshlib.dp_axes(mesh)
    params_shape = jax.eval_shape(
        lambda: RECSYS_MODULES[arch_id].init(jax.random.PRNGKey(0), cfg)
    )
    p_specs = recsys_param_specs(params_shape, mesh)
    inputs = shp.input_specs(cfg, spec)
    step = make_recsys_step(arch_id, cfg, mesh, spec.kind)

    if spec.kind == "recsys_retrieval":
        def step_masked(params, batch):
            batch = dict(batch)
            batch["n_real_candidates"] = m["n_candidates"]
            return step(params, batch)

        in_sh = {"candidate_ids": P(meshlib.all_axes(mesh)), "query": P()}
        fn = jax.jit(step_masked, in_shardings=(
            _shardings(mesh, p_specs), _shardings(mesh, in_sh)))
        return Cell(arch_id, spec.shape_id, fn, (params_shape, inputs),
                    {"kind": spec.kind})

    in_sh = {k: P(dp) if v.ndim == 1 else P(dp, None)
             for k, v in inputs.items()}
    if spec.kind == "recsys_serve":
        fn = jax.jit(step, in_shardings=(
            _shardings(mesh, p_specs), _shardings(mesh, in_sh)))
        return Cell(arch_id, spec.shape_id, fn, (params_shape, inputs),
                    {"kind": spec.kind})

    from repro.optim.rowwise import split_tree

    tab_shape, dense_shape = split_tree(params_shape)
    tab_specs, dense_specs = split_tree(p_specs)
    opt_shape = {
        **jax.eval_shape(adamw_init, dense_shape),
        "g2": {k: jax.ShapeDtypeStruct((v.shape[0],), jnp.float32)
               for k, v in tab_shape.items()},
    }
    tp = "model" if "model" in mesh.axis_names else None
    o_specs = {
        **opt_state_specs(dense_specs),
        "g2": {k: P(tp) for k in tab_shape},
    }
    fn = jax.jit(
        step,
        in_shardings=(
            _shardings(mesh, p_specs),
            _shardings(mesh, o_specs),
            _shardings(mesh, in_sh),
        ),
        out_shardings=(
            _shardings(mesh, p_specs),
            _shardings(mesh, o_specs), None,
        ),
        donate_argnums=(0, 1),
    )
    return Cell(arch_id, spec.shape_id, fn, (params_shape, opt_shape, inputs),
                {"kind": spec.kind})


# ==========================================================================
# RAGdb retrieval step (the paper's plane)
# ==========================================================================

def build_ragdb_cell(arch_id, cfg, spec: shp.ShapeSpec, mesh) -> Cell:
    from repro.core import retrieval as ret

    m = spec.meta
    axes = meshlib.all_axes(mesh)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    n_docs = m["docs_per_device"] * n_shards
    retrieve = ret.build_sharded_retrieve(
        mesh, axes, n_docs=n_docs, k=cfg.top_k,
        alpha=cfg.alpha, beta=cfg.beta,
    )
    fn = jax.jit(retrieve, in_shardings=(
        NamedSharding(mesh, P(axes, None)), NamedSharding(mesh, P(axes, None)),
        NamedSharding(mesh, P()), NamedSharding(mesh, P()),
    ))
    args = (
        jax.ShapeDtypeStruct((n_docs, cfg.dim), jnp.float32),
        jax.ShapeDtypeStruct((n_docs, cfg.sig_words), jnp.int32),
        jax.ShapeDtypeStruct((m["query_batch"], cfg.dim), jnp.float32),
        jax.ShapeDtypeStruct((m["query_batch"], cfg.sig_words), jnp.int32),
    )
    return Cell(arch_id, spec.shape_id, fn, args, {"kind": spec.kind})


# ==========================================================================
# entry point
# ==========================================================================

def build_cell(arch_id: str, shape_id: str, mesh, smoke: bool = False,
               optimized: bool = True) -> Cell:
    arch = get_arch(arch_id)
    cfg = arch.smoke_config if smoke else arch.config
    spec = shp.shapes_for_family(arch.family)[shape_id]
    if arch.family == "lm":
        if spec.kind == "lm_train":
            return build_lm_train_cell(arch_id, cfg, spec, mesh,
                                       optimized=optimized)
        if spec.kind == "lm_prefill":
            return build_lm_prefill_cell(arch_id, cfg, spec, mesh,
                                         optimized=optimized)
        return build_lm_decode_cell(arch_id, cfg, spec, mesh,
                                    optimized=optimized)
    if arch.family == "gnn":
        return build_gnn_cell(arch_id, cfg, spec, mesh)
    if arch.family == "recsys":
        return build_recsys_cell(arch_id, cfg, spec, mesh)
    if arch.family == "ragdb":
        return build_ragdb_cell(arch_id, cfg, spec, mesh)
    raise ValueError(arch.family)
