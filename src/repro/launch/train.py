"""End-to-end training driver.

Single-host entry point that exercises the full production loop on
whatever devices exist: deterministic data pipeline → jitted sharded
train step → async content-hashed checkpoints → exact restart-replay.
On a real cluster each host runs this same program under its
jax.distributed initialization; the mesh axes and sharding specs are
identical (launch/steps.py), only the device count changes.

    PYTHONPATH=src python -m repro.launch.train \
        --arch llama3.2-3b --smoke --steps 50 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get as get_arch
from repro.data.pipeline import DataCursor, lm_batch
from repro.launch import mesh as meshlib
from repro.launch import steps
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.runtime.straggler import StragglerDetector


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-feasible)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    assert arch.family == "lm", "train.py drives the LM family"
    cfg = arch.smoke_config if args.smoke else arch.config
    mesh = meshlib.make_host_mesh(args.model_parallel)
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name} "
          f"({cfg.param_count() / 1e6:.1f} M params)")

    params = T.init(jax.random.PRNGKey(args.seed), cfg)
    opt = adamw_init(params)
    cursor = DataCursor(seed=args.seed)

    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ck and ck.latest_step() is not None:
        state, start = ck.restore({"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        cursor.step = start
        print(f"restored checkpoint at step {start}")

    step_fn = jax.jit(steps.make_lm_train_step(
        cfg, mesh, args.n_micro, AdamWConfig(lr=args.lr, weight_decay=0.0)
    ), donate_argnums=(0, 1))
    detector = StragglerDetector()

    micro = args.batch // args.n_micro
    for s in range(start, args.steps):
        toks, tgts = lm_batch(cursor, args.batch, args.seq, cfg.vocab)
        toks = toks.reshape(args.n_micro, micro, args.seq)
        tgts = tgts.reshape(args.n_micro, micro, args.seq)
        t0 = time.perf_counter()
        params, opt, loss = step_fn(params, opt, jnp.asarray(toks),
                                    jnp.asarray(tgts))
        loss = float(loss)
        dt = time.perf_counter() - t0
        detector.observe("worker0", dt)
        if s % 10 == 0 or s == args.steps - 1:
            print(f"step {s:5d}  loss {loss:.4f}  {dt * 1e3:7.1f} ms")
        if ck and (s + 1) % args.ckpt_every == 0:
            ck.save_async(s + 1, {"params": params, "opt": opt})
    if ck:
        ck.wait()
    return float(loss)


if __name__ == "__main__":
    main()
