"""Deterministic spherical k-means over the TF-IDF doc matrix (pure JAX).

Documents rows are ℓ2-normalized (vectorizer.py), so cosine similarity
is a dot product and the natural cluster geometry is spherical: assign
by max dot against ℓ2-normalized centroids, update as the renormalized
member mean.  This is the training half of the IVF index plane
(ivf.py); EdgeRAG (arXiv:2412.21023) motivates exactly this primitive
for memory-constrained edge retrieval.

Determinism contract: the whole fit is a pure function of
(doc matrix, n_clusters, seed, n_iter) — init rows come from a seeded
``jax.random.permutation``, every step is jitted JAX arithmetic, and
empty-cluster reseeding is rank-based (no data-dependent host
branching) — so a retrain on the same corpus state reproduces the same
centroids bit-for-bit, which is what lets tests and the persistence
plane treat index state as replayable data.

Empty clusters: a cluster that loses all members seizes the
*worst-served* point (lowest best-similarity to any centroid); with
``e`` empty clusters the ``e`` hardest points are taken in rank order,
one per empty cluster.  This keeps k effective clusters without any
dynamic-shape escape to the host.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def default_n_clusters(n_docs: int) -> int:
    """The k ≈ √N default: balances centroid-scan cost (k·D per query)
    against candidate-scan cost (nprobe·N/k·D per probe)."""
    return max(1, int(round(math.sqrt(max(n_docs, 0)))))


@partial(jax.jit, static_argnames=("n_clusters", "n_iter"))
def _kmeans_fit(x: jnp.ndarray, init_rows: jnp.ndarray,
                *, n_clusters: int, n_iter: int):
    """Jitted Lloyd iterations on the sphere → (centroids, assign).

    x [N, D] float32 (rows ℓ2-normalized); init_rows [n_clusters] int32.
    """
    n = x.shape[0]
    cent = jnp.take(x, init_rows, axis=0)  # [k, D]

    def step(cent):
        # analysis: allow[unpinned-reduction] -- training geometry, not
        #   served scores: assignments feed routing only, and the exact
        #   HSF rerank makes results invariant to them
        sims = x @ cent.T                                  # [N, k]
        assign = jnp.argmax(sims, axis=1)
        best = jnp.max(sims, axis=1)                       # [N]
        one_hot = jax.nn.one_hot(assign, n_clusters, dtype=x.dtype)
        counts = one_hot.sum(axis=0)                       # [k]
        # analysis: allow[unpinned-reduction] -- centroid accumulation
        #   during training; same routing-only argument as above
        sums = one_hot.T @ x                               # [k, D]
        mean = sums / jnp.maximum(counts, 1.0)[:, None]
        # empty clusters seize the hardest points, one per cluster in
        # rank order (worst-served first) — deterministic, shape-static
        empty = counts == 0
        hardest = jnp.argsort(best)                        # ascending sim
        erank = jnp.clip(jnp.cumsum(empty) - 1, 0, n - 1)
        seize = jnp.take(x, jnp.take(hardest, erank), axis=0)
        cent = jnp.where(empty[:, None], seize, mean)
        norm = jnp.linalg.norm(cent, axis=1, keepdims=True)
        return cent / jnp.maximum(norm, 1e-12)             # spherical

    cent = jax.lax.fori_loop(0, n_iter, lambda _, c: step(c), cent)
    # analysis: allow[unpinned-reduction] -- final training assignment;
    #   routing-only, results invariant under the exact rerank
    assign = jnp.argmax(x @ cent.T, axis=1).astype(jnp.int32)
    return cent, assign


def spherical_kmeans(
    doc_vecs,
    n_clusters: int | None = None,
    *,
    seed: int = 0,
    n_iter: int = 8,
) -> tuple[np.ndarray, np.ndarray]:
    """Fit spherical k-means → (centroids [k, D] f32, assign [N] i32).

    ``n_clusters=None`` uses the √N default, clamped to N.  Fully
    deterministic from (doc_vecs, n_clusters, seed, n_iter).
    """
    x = jnp.asarray(doc_vecs, jnp.float32)
    n = int(x.shape[0])
    if n == 0:
        return (np.zeros((0, int(x.shape[1]) if x.ndim == 2 else 0),
                         np.float32),
                np.zeros((0,), np.int32))
    k = min(n_clusters or default_n_clusters(n), n)
    init = jax.random.permutation(jax.random.PRNGKey(seed), n)[:k]
    cent, assign = _kmeans_fit(x, init.astype(jnp.int32),
                               n_clusters=k, n_iter=n_iter)
    return np.asarray(cent), np.asarray(assign)
