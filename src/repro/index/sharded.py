"""Sharded IVF retrieval plane: the cluster index partitioned across a
JAX device mesh (docs/ARCHITECTURE.md §10).

Each shard (device) owns a disjoint subset of the IVF *clusters* —
centroids stay global (the probe plane is k_clusters ≈ √N, host-cheap),
but every cluster's member rows live on exactly one shard: the shard
holds a padded block of those rows' vectors and signatures, gathered in
ascending global-row order.  A query then runs:

1. **Global probe (host).**  Score the [k_clusters, D] centroid matrix
   once — the same interleaved probe order and (in exact mode) the same
   spherical-cap bound as the flat IVF path (`ivf.exact_cos_upper_bound`
   / `ivf.interleave_probe_order`), restricted per shard through the
   cluster→shard ownership map.

2. **Local rerank (per device).**  Each shard gathers its probed
   clusters' member rows from its resident block and scores them with
   the *bit-stable map formulation* (the same per-query matvec
   `_score_topk` dispatches), reducing to a local top-k.  Under
   `shard_map` this is one dispatch over the whole mesh; only the
   per-device ``[B, k]`` (vals, global ids, cos, contain) tuples cross
   the interconnect.

3. **Stable merge (host).**  The S·k candidates merge by
   (score desc, global id asc) — exactly `lax.top_k`'s tie rule on the
   flat score matrix, because each shard's local candidate order is the
   global row order restricted to that shard.

Exactness (``guarantee="exact"``): per-shard probe widths double until
the *merged* k-th exact score strictly beats every unprobed cluster's
cap bound in every shard (ties widen).  This is the unsharded exactness
theorem applied shard-wise: the bound says no unprobed cluster anywhere
can hold a doc scoring ≥ the current k-th, and per-shard local top-k +
stable merge reconstructs the global top-k of the probed union
bit-for-bit (asserted against ``index="flat"`` by
tests/test_index_sharded.py across shard counts, batch shapes, ragged
corpus sizes, tie-heavy corpora and degenerate partitions).

Cross-shard-count parity: the partition only decides *where* a cluster
is scored, never *what* is scored — the k-means fit, the probe bound,
the per-row dot products (bit-identical under row gather, the same
assumption the candidate-gather rerank already relies on) and the
merge rule are all partition-independent, so exact-mode results are
bit-identical across shards ∈ {1, 2, 4, 8, …} as well.

Incremental maintenance routes dirty rows to their owning shard off the
engine's existing dirty-row log: content-only changes scatter-patch the
owning shard's resident block in O(U) when the idf statistics held
still (the engine's own idf-stable fast path — an idf move rebuilds
every doc vector, and the blocks regather with it at the same O(N·D)
the reweight already paid); rows whose nearest centroid moved to a
cluster on another shard trigger a block regather for just the
affected shards; layout restacks rebuild the plane (the restack is
already O(N)).  All updates return a **new** ``ShardedIVFIndex`` — the
serving snapshots pin a frozen plane per generation with one reference
capture, same as the flat IVF index.

Persistence: ``state_dict`` extends the flat IVF state with the
cluster→shard map (segment ``ivf_shard_of_cluster``) and ``n_shards``,
under the same ``kind="ivf"`` — a sharded engine adopts a flat-written
state (deriving a deterministic partition) and vice versa (the flat
engine ignores the extra keys), and the same ``ids_sha`` content digest
rejects stale state per the exactness contract.

When fewer than ``n_shards`` devices exist (or n_shards == 1) the plane
falls back to a per-shard jitted loop on the default device — identical
block shapes, identical per-shard math, so logical-shard tests on one
CPU device exercise the exact same numerics the mesh dispatches.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hsf
from repro.core.engine import _bucket
from repro.obs import trace as obs_trace
from repro.index.ivf import (
    IVFIndex,
    IVFSearchStats,
    exact_cos_upper_bound,
    interleave_probe_order,
)
from repro.launch.mesh import make_shard_mesh

# pad sentinel for invalid rows in a shard's local top-k — loses every
# (score desc, id asc) merge (same sentinel the mesh retrieval path and
# the fused kernel use for unfillable rows)
_SENTINEL = np.int32(2**31 - 1)

P = jax.sharding.PartitionSpec


@dataclass(frozen=True)
class ShardedIVFSearchStats(IVFSearchStats):
    """Flat-IVF probe accounting plus the distribution terms."""

    n_shards: int = 1
    merge_seconds: float = 0.0   # host-side stable-merge time (all rounds)


def partition_clusters(sizes, n_shards: int) -> np.ndarray:
    """Deterministic balanced partition: cluster → shard.

    Greedy longest-processing-time: clusters sorted by (size desc,
    id asc) each go to the least-loaded shard (ties → lowest shard id).
    Pure function of (sizes, n_shards), so every engine that derives a
    partition for the same index state derives the *same* one — which
    is what lets a flat-written container adopt into a sharded engine
    reproducibly.
    """
    sizes = np.asarray(sizes, np.int64)
    out = np.zeros((sizes.size,), np.int32)
    load = np.zeros((n_shards,), np.int64)
    for c in np.lexsort((np.arange(sizes.size), -sizes)):
        s = int(np.argmin(load))        # argmin takes the lowest index on ties
        out[c] = s
        load[s] += sizes[c]
    return out


# --------------------------------------------------------------------------
# per-shard local scorer (the map formulation, over a resident block)
# --------------------------------------------------------------------------

def _shard_topk_core(dv, ds, gids, cand, n_cand, qv, qs, *, kk, alpha, beta):
    """Local top-k over one shard's candidate gather.

    ``dv``/``ds``/``gids`` are the shard's resident [L, D]/[L, W]/[L]
    block; ``cand`` [C] are local candidate rows (ascending → the
    gathered order is the global row order restricted to this shard, so
    ``lax.top_k``'s index-ascending tie rule matches the flat scan);
    ``n_cand`` (traced) masks the candidate pad.  The cosine is
    ``hsf.stable_rowdot`` — the pinned-reduction-order matvec shared
    with the flat engine's map path — which is what makes each
    candidate's score bit-identical to its row in the full scan
    regardless of block height, gather fusion, or which device runs it.
    """
    sub_v = jnp.take(dv.astype(jnp.float32), cand, axis=0)
    sub_s = jnp.take(ds, cand, axis=0)
    sub_g = jnp.take(gids, cand)
    cos = jax.lax.map(lambda q: hsf.stable_rowdot(sub_v, q), qv)
    ind = jax.vmap(lambda s: hsf.containment(sub_s, s))(qs)
    scores = alpha * cos + beta * ind
    scores = jnp.where(
        jnp.arange(scores.shape[1])[None, :] < n_cand, scores, -jnp.inf
    )
    vals, li = jax.lax.top_k(scores, kk)
    gi = jnp.where(vals > -jnp.inf, jnp.take(sub_g, li),
                   jnp.int32(_SENTINEL))
    return (vals, gi, jnp.take_along_axis(cos, li, axis=1),
            jnp.take_along_axis(ind, li, axis=1))


_shard_topk_jit = jax.jit(
    _shard_topk_core, static_argnames=("kk", "alpha", "beta")
)


@lru_cache(maxsize=64)
def _mesh_topk_fn(mesh, kk: int, alpha: float, beta: float):
    """jit(shard_map(local top-k)) for one (mesh, k, α, β): each device
    scores its own block; only the [B, kk] result tuples leave it."""
    def local_fn(dv, ds, gids, cand, n_cand, qv, qs):
        out = _shard_topk_core(dv[0], ds[0], gids[0], cand[0], n_cand[0],
                               qv, qs, kk=kk, alpha=alpha, beta=beta)
        return tuple(o[None] for o in out)

    return jax.jit(jax.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P("shards"), P("shards"), P("shards"),
                  P("shards"), P("shards"), P(), P()),
        out_specs=(P("shards"),) * 4,
        check_vma=False,
    ))


@jax.jit
def _scatter_block_rows(s_idx, l_idx, vec_block, sig_block,
                        dv_stack, ds_stack):
    """Content patch: write U changed rows into their owning shards'
    resident blocks — one fused dispatch for both scatters."""
    return (dv_stack.at[s_idx, l_idx].set(vec_block),
            ds_stack.at[s_idx, l_idx].set(sig_block))


@partial(jax.jit, static_argnames=("block_len",))
def _gather_shard_block(doc_vecs, doc_sigs, rows, n_rows, *, block_len):
    """One shard's padded resident block, gathered on device —
    ``rows`` [L] (pad rows duplicate row 0; masked by ``n_rows``)."""
    dv = jnp.take(doc_vecs, rows, axis=0).astype(jnp.float32)
    ds = jnp.take(doc_sigs, rows, axis=0).astype(jnp.int32)
    valid = jnp.arange(block_len) < n_rows
    dv = jnp.where(valid[:, None], dv, 0.0)
    ds = jnp.where(valid[:, None], ds, 0)
    return dv, ds


@dataclass(frozen=True)
class ShardedIVFIndex:
    """Immutable cluster-sharded index plane (see module docstring).

    ``base`` carries the global IVF state (centroids, bounds, assign,
    members) — probing, maintenance bookkeeping and persistence all
    delegate to it, so the sharded plane provably prunes with the same
    bound the flat IVF search uses.  The fields below it are the
    distribution plane: ownership, per-shard row sets, and the padded
    device-resident blocks the local reranks score.
    """

    base: IVFIndex
    n_shards: int
    shard_of_cluster: np.ndarray  # [kc] int32 — cluster → owning shard
    shard_rows: tuple             # S × int32 [n_s] ascending global rows
    block_len: int                # L — power-of-two row pad per shard
    dv_stack: object              # jnp [S, L, D] (mesh-sharded on dim 0)
    ds_stack: object              # jnp [S, L, W]
    gid_stack: object             # jnp [S, L] int32 (pad = sentinel)
    mesh: object | None           # 1-D ("shards",) Mesh, or None = loop

    # ---- construction ---------------------------------------------------

    @staticmethod
    def train(doc_vecs, doc_sigs, *, n_clusters: int | None = None,
              seed: int = 0, n_iter: int = 8,
              n_shards: int = 1) -> "ShardedIVFIndex":
        """Fit the (partition-independent) k-means, then shard it."""
        base = IVFIndex.train(doc_vecs, doc_sigs, n_clusters=n_clusters,
                              seed=seed, n_iter=n_iter)
        return ShardedIVFIndex.from_base(base, doc_vecs, doc_sigs,
                                         n_shards=n_shards)

    @staticmethod
    def from_base(base: IVFIndex, doc_vecs, doc_sigs, *, n_shards: int,
                  shard_of_cluster=None) -> "ShardedIVFIndex":
        """Build the distribution plane over an existing IVF state.

        ``shard_of_cluster`` overrides the deterministic balanced
        partition (tests use it for degenerate all-in-one-shard
        ownership); it must map every cluster to [0, n_shards).
        """
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if shard_of_cluster is None:
            sizes = [m.size for m in base.members]
            shard_of_cluster = partition_clusters(sizes, n_shards)
        else:
            shard_of_cluster = np.asarray(shard_of_cluster, np.int32)
            if shard_of_cluster.shape != (base.n_clusters,):
                raise ValueError(
                    f"shard_of_cluster must have shape ({base.n_clusters},), "
                    f"got {shard_of_cluster.shape}"
                )
            if shard_of_cluster.size and (
                    shard_of_cluster.min() < 0
                    or shard_of_cluster.max() >= n_shards):
                raise ValueError("shard_of_cluster entries must lie in "
                                 f"[0, {n_shards})")
        shard_rows = _shard_rows_from(base, shard_of_cluster, n_shards)
        return _build_plane(base, n_shards, shard_of_cluster, shard_rows,
                            doc_vecs, doc_sigs)

    @staticmethod
    def from_state(state: dict, doc_vecs, doc_sigs, *,
                   n_shards: int) -> "ShardedIVFIndex":
        """Adopt persisted IVF state (flat- or sharded-written) —
        bit-identical bounds/assignments, no retrain; the persisted
        partition is reused when it was written for the same shard
        count, else a deterministic one is derived."""
        base = IVFIndex.from_state(state)
        soc = state.get("shard_of_cluster")
        if soc is not None and int(state.get("n_shards", -1)) == n_shards:
            soc = np.asarray(soc, np.int32)
        else:
            soc = None
        return ShardedIVFIndex.from_base(base, doc_vecs, doc_sigs,
                                         n_shards=n_shards,
                                         shard_of_cluster=soc)

    def state_dict(self, layout_keys) -> dict:
        """The flat IVF state plus the ownership map — still
        ``kind="ivf"`` so flat and sharded engines adopt each other's
        containers (core/ingest.py journals ``ivf_shard_of_cluster`` as
        one more index segment)."""
        st = self.base.state_dict(layout_keys)
        st["n_shards"] = int(self.n_shards)
        st["shard_of_cluster"] = self.shard_of_cluster
        return st

    # ---- delegation (engine/serving introspection + tests) --------------

    @property
    def n_clusters(self) -> int:
        return self.base.n_clusters

    @property
    def n_docs(self) -> int:
        return self.base.n_docs

    @property
    def centroids(self) -> np.ndarray:
        return self.base.centroids

    @property
    def assign(self) -> np.ndarray:
        return self.base.assign

    @property
    def members(self) -> tuple:
        return self.base.members

    @property
    def sig_union(self) -> np.ndarray:
        return self.base.sig_union

    @property
    def radius(self) -> np.ndarray:
        return self.base.radius

    @property
    def drift(self) -> int:
        return self.base.drift

    @property
    def trained_n(self) -> int:
        return self.base.trained_n

    @property
    def seed(self) -> int:
        return self.base.seed

    def needs_retrain(self, retrain_drift: float) -> bool:
        return self.base.needs_retrain(retrain_drift)

    def shard_sizes(self) -> list[int]:
        return [int(r.size) for r in self.shard_rows]

    # ---- incremental maintenance (engine dirty-row log) -----------------

    def reassign(self, rows, row_vecs, row_sigs, doc_vecs, doc_sigs, *,
                 reweighted: bool = False) -> "ShardedIVFIndex":
        """Route dirty rows to their owning shard.

        Delegates the cluster moves and bound widening to
        ``base.reassign`` (same drift accounting as the flat index),
        then repairs the device plane: rows whose old and new clusters
        live on the same shard only need their block content
        scatter-patched (O(U) — the shard's row set didn't change);
        rows that crossed shards invalidate both shards' row sets, so
        those shards' blocks regather from the live doc arrays
        (O(rows-on-affected-shards), never O(N) unless a shard outgrew
        its pad bucket, which rebuilds the plane like a restack).

        ``reweighted=True`` signals that the engine's refresh moved the
        idf statistics, i.e. *every* doc vector was rebuilt, not just
        the dirty rows — the resident blocks then regather in full
        (the refresh already paid O(N·D) for the reweight, so this
        keeps the same asymptotics; the O(U) patch path is exactly the
        engine's own idf-stable fast path, mirrored).
        """
        rows = np.asarray(rows, np.int32)
        if rows.size == 0:
            return self
        new_base = self.base.reassign(rows, row_vecs, row_sigs)
        if reweighted:
            return ShardedIVFIndex.from_base(
                new_base, doc_vecs, doc_sigs, n_shards=self.n_shards,
                shard_of_cluster=self.shard_of_cluster,
            )
        old_shard = self.shard_of_cluster[self.base.assign[rows]]
        new_shard = self.shard_of_cluster[new_base.assign[rows]]
        crossed = np.unique(np.concatenate(
            [old_shard[old_shard != new_shard],
             new_shard[old_shard != new_shard]]
        ))
        if crossed.size:
            new_rows = _shard_rows_from(new_base, self.shard_of_cluster,
                                        self.n_shards)
            if max(r.size for r in new_rows) > self.block_len:
                # a shard outgrew the row bucket: rebuild (rare — the
                # bucket doubles, so this amortizes like the restack)
                return _build_plane(new_base, self.n_shards,
                                    self.shard_of_cluster, new_rows,
                                    doc_vecs, doc_sigs)
        else:
            new_rows = self.shard_rows

        dv_stack, ds_stack, gid_stack = (
            self.dv_stack, self.ds_stack, self.gid_stack
        )
        # regather the shards whose row sets changed
        gid_host = None
        for s in crossed:
            srows = new_rows[s]
            padded = np.zeros((self.block_len,), np.int32)
            padded[: srows.size] = srows
            dv_s, ds_s = _gather_shard_block(
                doc_vecs, doc_sigs, jnp.asarray(padded),
                jnp.int32(srows.size), block_len=self.block_len,
            )
            dv_stack = dv_stack.at[int(s)].set(dv_s)
            ds_stack = ds_stack.at[int(s)].set(ds_s)
            if gid_host is None:
                gid_host = np.asarray(gid_stack).copy()
            gid_host[int(s)] = _SENTINEL
            gid_host[int(s), : srows.size] = srows
        if gid_host is not None:
            gid_stack = jnp.asarray(gid_host)

        # scatter-patch content for rows that stayed on their shard
        crossed_set = set(int(s) for s in crossed)
        keep = np.array([new_shard[j] not in crossed_set
                         and old_shard[j] not in crossed_set
                         for j in range(rows.size)], bool)
        if keep.any():
            s_idx = new_shard[keep].astype(np.int32)
            l_idx = np.array(
                [int(np.searchsorted(new_rows[s], r))
                 for s, r in zip(s_idx, rows[keep])], np.int32,
            )
            vec_block = np.asarray(row_vecs, np.float32)[keep]
            sig_block = np.asarray(row_sigs, np.int32)[keep]
            # pad the scatter to a power-of-two row count (bounded jit
            # recompiles; duplicate writes of identical content are
            # deterministic — same trick as engine._pad_row_update)
            pad = _bucket(int(keep.sum())) - int(keep.sum())
            if pad:
                s_idx = np.concatenate([s_idx, np.repeat(s_idx[:1], pad)])
                l_idx = np.concatenate([l_idx, np.repeat(l_idx[:1], pad)])
                vec_block = np.concatenate(
                    [vec_block, np.repeat(vec_block[:1], pad, axis=0)])
                sig_block = np.concatenate(
                    [sig_block, np.repeat(sig_block[:1], pad, axis=0)])
            dv_stack, ds_stack = _scatter_block_rows(
                jnp.asarray(s_idx), jnp.asarray(l_idx),
                jnp.asarray(vec_block), jnp.asarray(sig_block),
                dv_stack, ds_stack,
            )
        dv_stack, ds_stack, gid_stack = _pin_stacks(
            self.mesh, dv_stack, ds_stack, gid_stack
        )
        return replace(self, base=new_base, shard_rows=new_rows,
                       dv_stack=dv_stack, ds_stack=ds_stack,
                       gid_stack=gid_stack)

    def remap(self, carried_assign, doc_vecs, doc_sigs) -> "ShardedIVFIndex":
        """Rebuild after an engine layout restack — the restack is
        already O(N), so the plane regathers in full.  Centroids (and
        therefore the partition) are unchanged."""
        new_base = self.base.remap(carried_assign, doc_vecs, doc_sigs)
        return ShardedIVFIndex.from_base(
            new_base, doc_vecs, doc_sigs, n_shards=self.n_shards,
            shard_of_cluster=self.shard_of_cluster,
        )

    # ---- the sharded two-stage search -----------------------------------

    def search(self, doc_vecs, doc_sigs, qv: np.ndarray, qs: np.ndarray, *,
               b: int, k: int, nprobe: int, guarantee: str,
               scoring_path: str, alpha: float, beta: float,
               explain: bool = False):
        """Probe globally, rerank per shard, merge stably → the same
        (vals, idx, cos, ind, stats) contract as ``IVFIndex.search``
        (idx are global doc rows).

        ``scoring_path`` is accepted for signature compatibility; the
        local rerank always scores with the bit-stable map formulation
        (the engine rejects explicit gemm/kernel for this index kind).
        In exact mode, per-(query, shard) probe widths double until the
        merged k-th exact score strictly beats every unprobed cluster's
        spherical-cap bound in that shard; in probe mode each shard
        scores the batch union of its queries' top-``nprobe`` local
        clusters in a single round (a per-query superset of the flat
        IVF probe — recall can only improve).
        """
        del scoring_path
        base = self.base
        n, kc, S = base.n_docs, base.n_clusters, self.n_shards
        kk = min(k, n)
        sizes = np.array([m.size for m in base.members], np.int64)
        _t = time.perf_counter() if obs_trace.active() else 0.0

        # -- global probe plane (host, float64 bound) ---------------------
        # analysis: allow[unpinned-reduction] -- f64 probe bound, clipped
        #   to [-1,1]; prunes candidates only, exact rerank follows
        a = np.clip(
            qv[:b].astype(np.float64) @ base.centroids.T.astype(np.float64),
            -1.0, 1.0,
        )
        qsig = qs[:b].astype(np.int32)
        contain = np.all(
            (base.sig_union[None, :, :] & qsig[:, None, :])
            == qsig[:, None, :], axis=2,
        )
        if guarantee == "exact":
            ub = alpha * exact_cos_upper_bound(a, base.radius) \
                + beta * contain
            rank = ub
        else:
            ub = None
            rank = alpha * a + beta * contain
        order = interleave_probe_order(rank, a)             # [b, kc]

        # restrict the global order to each shard's clusters (the
        # restriction of a permutation is a permutation of the subset,
        # so per-shard probing follows the same priority as the flat
        # IVF search would within that shard)
        soc = self.shard_of_cluster
        shard_orders = []
        for s in range(S):
            own = soc[order] == s                           # [b, kc] bool
            kc_s = int((soc == s).sum())
            shard_orders.append(
                order[own].reshape(b, kc_s) if kc_s else
                np.empty((b, 0), np.int64)
            )

        # initial probe width per (shard, query): nprobe clamped to the
        # shard's cluster count, widened until the shard's own probed
        # clusters cover ≥ min(kk, n_s) docs — summed over shards that
        # guarantees ≥ kk real candidates, so the merged top-k is full
        p = np.zeros((S, b), np.int64)
        for s in range(S):
            kc_s = shard_orders[s].shape[1]
            if kc_s == 0:
                continue
            n_s = int(self.shard_rows[s].size)
            need_docs = min(kk, n_s)
            for i in range(b):
                csum = np.cumsum(sizes[shard_orders[s][i]])
                need = int(np.searchsorted(csum, need_docs)) + 1
                p[s, i] = min(max(min(max(nprobe, 1), kc_s), need), kc_s)

        if _t:
            obs_trace.record("shard_probe", _t, time.perf_counter() - _t,
                             clusters=kc, shards=S, queries=b,
                             guarantee=guarantee)
        shard_cluster_ids = [np.nonzero(soc == s)[0] for s in range(S)]
        qv_j, qs_j = jnp.asarray(qv), jnp.asarray(qs)
        rounds = 0
        merge_seconds = 0.0
        while True:
            rounds += 1
            _tr = time.perf_counter() if obs_trace.active() else 0.0
            cand_local: list[np.ndarray] = []
            probed_global: list[np.ndarray] = []
            for s in range(S):
                kc_s = shard_orders[s].shape[1]
                n_s = int(self.shard_rows[s].size)
                if kc_s == 0 or n_s == 0:
                    cand_local.append(np.zeros((0,), np.int32))
                    probed_global.append(shard_cluster_ids[s])
                    continue
                probed = np.unique(np.concatenate(
                    [shard_orders[s][i, : p[s, i]] for i in range(b)]
                ))
                if probed.size >= kc_s or sizes[probed].sum() * 2 > n_s:
                    # ≥50% of the shard probed: score the whole resident
                    # block — the shard-local analogue of the flat-scan
                    # collapse, trivially exact for this shard
                    cand_local.append(
                        np.arange(n_s, dtype=np.int32))
                    probed_global.append(shard_cluster_ids[s])
                else:
                    gmem = np.sort(np.concatenate(
                        [base.members[c] for c in probed]
                    ))
                    cand_local.append(np.searchsorted(
                        self.shard_rows[s], gmem).astype(np.int32))
                    probed_global.append(probed)

            C = _bucket(max(1, max(c.size for c in cand_local)))
            kk_loc = min(kk, C)
            cand_pad = np.zeros((S, C), np.int32)
            n_cand = np.zeros((S,), np.int32)
            for s, cl in enumerate(cand_local):
                cand_pad[s, : cl.size] = cl
                n_cand[s] = cl.size
            svals, sgids, scos, sind = self._dispatch(
                cand_pad, n_cand, qv_j, qs_j, kk_loc, alpha, beta
            )
            t0 = time.perf_counter()
            vals, idx, cos, ind = _merge_shard_topk(
                svals, sgids, scos, sind, kk
            )
            t1 = time.perf_counter()
            merge_seconds += t1 - t0
            if _tr:
                obs_trace.record("shard_merge", t0, t1 - t0,
                                 shards=S, round=rounds)
                obs_trace.record("shard_round", _tr, t1 - _tr,
                                 round=rounds,
                                 candidates=int(n_cand.sum()))

            if ub is None:
                break
            # stop test, per (query, shard): the merged k-th exact score
            # must strictly beat every unprobed cluster's bound in every
            # shard (ties could displace by doc-index order → widen)
            done = True
            for s in range(S):
                kc_s = shard_orders[s].shape[1]
                if kc_s == 0 or probed_global[s].size >= kc_s:
                    continue
                mask = np.zeros((kc,), bool)
                mask[probed_global[s]] = True
                un = shard_cluster_ids[s][~mask[shard_cluster_ids[s]]]
                for i in range(b):
                    if float(vals[i, kk - 1]) <= ub[i, un].max():
                        p[s, i] = min(p[s, i] * 2, kc_s)
                        done = False
            if done:
                break

        probe_orders, kth, bounds = [], [], []
        if explain:
            mask = np.zeros((kc,), bool)
            for pg in probed_global:
                mask[pg] = True
            for i in range(b):
                own = np.concatenate([
                    shard_orders[s][i, : min(int(p[s, i]),
                                             shard_orders[s].shape[1])]
                    for s in range(S)
                ]) if S else np.zeros((0,), np.int64)
                probe_orders.append(tuple(int(c) for c in own))
                kth.append(float(vals[i, kk - 1]))
                if ub is None:
                    bounds.append(None)
                else:
                    un = ub[i][~mask]
                    bounds.append(float(un.max()) if un.size else None)
        stats = ShardedIVFSearchStats(
            n_docs=n,
            candidate_rows=int(n_cand.sum()),
            clusters_probed=int(sum(pg.size for pg in probed_global)),
            n_clusters=kc,
            rounds=rounds,
            probe_order=tuple(probe_orders),
            kth_scores=tuple(kth),
            unprobed_bounds=tuple(bounds),
            n_shards=S,
            merge_seconds=merge_seconds,
        )
        return vals, idx, cos, ind, stats

    def _dispatch(self, cand_pad, n_cand, qv_j, qs_j, kk_loc, alpha, beta):
        """One rerank round → numpy (vals, gids, cos, ind), each
        [S, Bp, kk_loc].  Mesh path: one ``shard_map`` dispatch, each
        device scoring its resident block; only its [B, kk] tuple
        leaves the device.  Fallback: the identical jitted local scorer
        looped over logical shards on the default device."""
        if self.mesh is not None:
            # one collective dispatch: per-shard attribution is not
            # observable from the host, so a single span covers it
            with obs_trace.span("shard_local_topk",
                                shards=self.n_shards, mode="mesh"):
                fn = _mesh_topk_fn(self.mesh, kk_loc,
                                   float(alpha), float(beta))
                v, g, c, d = fn(self.dv_stack, self.ds_stack,
                                self.gid_stack,
                                jnp.asarray(cand_pad),
                                jnp.asarray(n_cand),
                                qv_j, qs_j)
                if obs_trace.active():
                    jax.block_until_ready(v)  # analysis: allow[host-sync] -- tracing/explain-only audited boundary attributing mesh dispatch time to its span; no-op when both are off
        else:
            outs = []
            for s in range(self.n_shards):
                with obs_trace.span("shard_local_topk", shard=s,
                                    rows=int(n_cand[s])):
                    o = _shard_topk_jit(
                        self.dv_stack[s], self.ds_stack[s],
                        self.gid_stack[s],
                        jnp.asarray(cand_pad[s]),
                        jnp.int32(int(n_cand[s])),
                        qv_j, qs_j,
                        kk=kk_loc, alpha=float(alpha), beta=float(beta),
                    )
                    if obs_trace.active():
                        jax.block_until_ready(o)  # analysis: allow[host-sync] -- tracing/explain-only audited boundary: per-shard local-top-k attribution in the logical-shard loop; no-op when both are off
                outs.append(o)
            v = jnp.stack([o[0] for o in outs])
            g = jnp.stack([o[1] for o in outs])
            c = jnp.stack([o[2] for o in outs])
            d = jnp.stack([o[3] for o in outs])
        return (np.asarray(v), np.asarray(g), np.asarray(c), np.asarray(d))


# --------------------------------------------------------------------------
# plane construction + merge
# --------------------------------------------------------------------------

def _shard_rows_from(base: IVFIndex, shard_of_cluster: np.ndarray,
                     n_shards: int) -> tuple:
    """Ascending global member rows per shard (union of owned clusters)."""
    out = []
    for s in range(n_shards):
        own = np.nonzero(shard_of_cluster == s)[0]
        if own.size:
            rows = np.sort(np.concatenate(
                [base.members[c] for c in own]
            )).astype(np.int32)
        else:
            rows = np.zeros((0,), np.int32)
        out.append(rows)
    return tuple(out)


def _pin_stacks(mesh, dv_stack, ds_stack, gid_stack):
    """Commit the stacked blocks to the mesh (dim 0 = shard axis) — one
    device_put each; a no-op when already resident with that sharding."""
    if mesh is None:
        return dv_stack, ds_stack, gid_stack
    sh = jax.sharding.NamedSharding(mesh, P("shards"))
    return (jax.device_put(dv_stack, sh), jax.device_put(ds_stack, sh),
            jax.device_put(gid_stack, sh))


def _build_plane(base: IVFIndex, n_shards: int, shard_of_cluster: np.ndarray,
                 shard_rows: tuple, doc_vecs, doc_sigs) -> ShardedIVFIndex:
    """Materialize the per-shard resident blocks (O(N) gather — only at
    train/adopt/restack time, never on the query path)."""
    L = _bucket(max(1, max((r.size for r in shard_rows), default=1)))
    dim = np.shape(doc_vecs)[1] if np.ndim(doc_vecs) == 2 else 0
    w = np.shape(doc_sigs)[1] if np.ndim(doc_sigs) == 2 else 0
    dvn = np.asarray(doc_vecs, np.float32)
    dsn = np.asarray(doc_sigs, np.int32)
    dv = np.zeros((n_shards, L, dim), np.float32)
    ds = np.zeros((n_shards, L, w), np.int32)
    gid = np.full((n_shards, L), _SENTINEL, np.int32)
    for s, rows in enumerate(shard_rows):
        if rows.size:
            dv[s, : rows.size] = dvn[rows]
            ds[s, : rows.size] = dsn[rows]
            gid[s, : rows.size] = rows
    mesh = make_shard_mesh(n_shards)
    dv_j, ds_j, gid_j = _pin_stacks(
        mesh, jnp.asarray(dv), jnp.asarray(ds), jnp.asarray(gid)
    )
    return ShardedIVFIndex(
        base=base, n_shards=int(n_shards),
        shard_of_cluster=np.asarray(shard_of_cluster, np.int32),
        shard_rows=shard_rows, block_len=int(L),
        dv_stack=dv_j, ds_stack=ds_j, gid_stack=gid_j, mesh=mesh,
    )


def _merge_shard_topk(vals, gids, cos, ind, kk: int):
    """Stable global merge of per-shard top-k lists.

    Sort key (score desc, global id asc) — exactly ``lax.top_k``'s tie
    rule on the flat score matrix.  Sentinel-id rows carry -inf scores
    and lose every comparison; the per-shard coverage widening
    guarantees ≥ kk real candidates, so they never surface.
    """
    s, bp, kl = vals.shape
    v = np.swapaxes(vals, 0, 1).reshape(bp, s * kl)
    g = np.swapaxes(gids, 0, 1).reshape(bp, s * kl)
    c = np.swapaxes(cos, 0, 1).reshape(bp, s * kl)
    d = np.swapaxes(ind, 0, 1).reshape(bp, s * kl)
    pick = np.lexsort((g, -v), axis=-1)[:, :kk]
    return (np.take_along_axis(v, pick, axis=1),
            np.take_along_axis(g, pick, axis=1).astype(np.int32),
            np.take_along_axis(c, pick, axis=1),
            np.take_along_axis(d, pick, axis=1))
