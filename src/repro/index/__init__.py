"""The clustered index plane (docs/ARCHITECTURE.md §9).

A new layer between the vectorizer and the scorer: instead of scanning
all N documents per query (map/gemm/kernel all do), the IVF index
scores a [k_clusters, D] centroid matrix, probes the top-``nprobe``
clusters, and runs the **exact** HSF (cosine + substring boost) over
the gathered candidate rows through the same
``score_batch_arrays``/``hsf_score_topk_pallas`` machinery the flat
paths use — so results within the probed set are bit-identical to the
brute-force scan, and ``guarantee="exact"`` widens the probe set until
the top-k is provably stable (see ivf.py for the bound).

- ``kmeans.py``  — deterministic spherical k-means over the TF-IDF doc
  matrix in pure JAX (k ≈ √N default, empty-cluster reseeding).
- ``ivf.py``     — cluster assignment, probe/rerank search, incremental
  maintenance off the engine's dirty-row log, and the container
  (de)serialization the persistence plane journals.
- ``sharded.py`` — the cluster plane partitioned across a device mesh
  (``shard_map``): each device owns a disjoint cluster subset and
  reranks it locally; only per-device [B, k] top-k candidates cross
  the interconnect for a stable merge, with the same exactness bound
  applied per shard (docs/ARCHITECTURE.md §10).

Consumed by ``QueryEngine(index="ivf" | "ivf-sharded")``
(core/engine.py); frozen per-generation by the serving snapshots
(serving/snapshot.py).
"""
from repro.index.kmeans import default_n_clusters, spherical_kmeans
from repro.index.ivf import IVFIndex, IVFSearchStats, score_candidate_rows
from repro.index.sharded import (
    ShardedIVFIndex,
    ShardedIVFSearchStats,
    partition_clusters,
)

__all__ = [
    "IVFIndex",
    "IVFSearchStats",
    "ShardedIVFIndex",
    "ShardedIVFSearchStats",
    "default_n_clusters",
    "partition_clusters",
    "score_candidate_rows",
    "spherical_kmeans",
]
