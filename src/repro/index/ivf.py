"""IVF-style clustered retrieval: probe top-``nprobe`` clusters, rerank
with the exact HSF.

Two-stage query (docs/ARCHITECTURE.md §9):

1. **Probe.**  Score the [k_clusters, D] centroid matrix (host numpy —
   k_clusters ≈ √N, this is the cheap plane).  The probe order
   interleaves the *optimistic HSF* ranking
   ``α·(q·μ_c) + β·contain(∪sig_c, q_sig)`` — ``∪sig_c`` is the
   bitwise OR of the cluster members' Bloom signatures, so a cluster
   whose union cannot contain the query substring provably holds no
   boosted doc — with the pure centroid-cosine ranking (on big
   clusters the union saturates and ``contain`` fires broadly; cosine
   keeps the semantic neighborhoods ranked).

2. **Rerank.**  Gather the probed clusters' member rows — per query in
   probe mode, the batch union in exact mode — in ascending global row
   order (so tie-breaking matches the flat scan) and score them
   through the *same* ``score_batch_arrays`` machinery the flat paths
   use (map / gemm / fused Pallas kernel).  Each gathered row is
   scored by the identical jitted formulation as the flat scan, so
   results within the probed set equal the brute-force results —
   asserted bit-for-bit (ids, scores, tie order) by the exactness
   sweep in tests/test_index.py and the CI smoke step of
   benchmarks/bench_index.py.

Exactness guarantee (``guarantee="exact"``): every doc d in cluster c
satisfies ``score(q, d) ≤ α·cos_ub(q, c) + β·contain(∪sig_c, q_sig)``
where ``cos_ub`` is the spherical-cap bound ``cos(max(0, θ_q − θ_c))``
computed from the stored per-cluster radius (min member·centroid dot —
kept as a *lower* bound under incremental maintenance, which only ever
widens the cap: stale radius/union bits make probing conservative,
never unsafe).  The search widens the probe set until the k-th best
exact score strictly exceeds every unprobed cluster's bound (ties
force further probing), at which point the top-k — ids, scores, tie
order — is provably identical to the flat scan.  The bound is
evaluated in float64 with a +1e-6 margin so float rounding can only
over-probe.  Requires ``α ≥ 0`` and ``β ≥ 0`` (enforced by the engine).

Incremental maintenance: ``reassign`` moves changed rows to their
nearest centroid in O(U·k_clusters·D) and widens the affected
clusters' bounds; ``remap`` handles layout restacks; a drift counter
(rows that changed cluster since the last train) triggers retraining
once it exceeds a configurable fraction of the corpus
(``needs_retrain``).  All updates return a **new** ``IVFIndex`` —
instances are immutable after construction, which is what lets the
serving snapshots pin a frozen index per generation with one reference
capture (serving/snapshot.py).
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitizers
from repro.core.engine import _bucket, score_batch_arrays
from repro.index.kmeans import spherical_kmeans
from repro.obs import trace as obs_trace

# float64 safety margin on the spherical-cap bound: rounding can only
# widen the probe set, never exclude a true top-k doc
_UB_EPS = 1e-6


def exact_cos_upper_bound(a: np.ndarray, radius: np.ndarray) -> np.ndarray:
    """Spherical-cap cosine bound ``cos(max(0, θ_q − θ_c))`` per
    (query, cluster), in float64 with the over-probe cushions.

    ``a`` [b, kc] are clipped query·centroid cosines; ``radius`` [kc] is
    the stored min member·centroid dot.  The stored radius is an f32
    dot; its rounding error is amplified by the cap's curvature near
    rb → 1 (d cap/d rb ~ 1/√(1−rb²)), so cushion rb by 1e-4 — widening
    the cap can only over-probe, never exclude a true top-k doc.  Shared
    by the flat IVF search and the per-shard bound of the sharded plane
    (index/sharded.py) — one bound, one proof.
    """
    rb = np.clip(radius.astype(np.float64) - 1e-4, -1.0, 1.0)[None, :]
    cap = a * rb + np.sqrt(np.maximum(1 - a * a, 0.0)) \
        * np.sqrt(np.maximum(1 - rb * rb, 0.0))
    return np.where(a >= rb, 1.0, cap) + _UB_EPS


def interleave_probe_order(boosted_rank: np.ndarray,
                           a: np.ndarray) -> np.ndarray:
    """Per-query cluster probe order [b, kc]: the boost-aware ranking
    interleaved with pure centroid cosine (see ``IVFIndex.search`` for
    why both are needed), duplicates dropped at first occurrence."""
    b, kc = boosted_rank.shape
    order = np.empty((b, kc), np.int64)
    o_boost = np.argsort(-boosted_rank, axis=1, kind="stable")
    o_cos = np.argsort(-a, axis=1, kind="stable")
    for i in range(b):
        merged = np.ravel(np.column_stack((o_boost[i], o_cos[i])))
        _, first = np.unique(merged, return_index=True)
        order[i] = merged[np.sort(first)]
    return order


def ids_digest(keys) -> str:
    """Digest of the corpus layout the index state was computed against.

    ``keys`` must identify both the doc-id *ordering* and each doc's
    *content* (the engine passes ``"id\\x01sha256"`` strings —
    ``QueryEngine._ivf_state_key``): an in-place rewrite with no live
    index maintenance must invalidate adoption, because stale
    sig_union/radius bounds for the rewritten doc could *underestimate*
    its cluster and silently break the exactness guarantee.
    """
    h = hashlib.sha256()
    for k in keys:
        h.update(k.encode("utf-8"))
        h.update(b"\x00")
    return h.hexdigest()


@dataclass(frozen=True)
class IVFSearchStats:
    """What one ``search`` actually scanned.

    The per-query EXPLAIN fields (``probe_order`` / ``kth_scores`` /
    ``unprobed_bounds``) are populated only under ``explain=True`` —
    empty tuples on the hot path, so steady-state search allocates
    nothing extra."""

    n_docs: int
    candidate_rows: int     # doc rows gathered + exactly scored
    clusters_probed: int
    n_clusters: int
    rounds: int             # probe-widening rounds (1 unless exact mode)
    probe_order: tuple = ()      # per-query tuples of probed cluster ids
    kth_scores: tuple = ()       # per-query final kth candidate score
    unprobed_bounds: tuple = ()  # per-query max unprobed bound (or None)

    @property
    def probed_fraction(self) -> float:
        return self.candidate_rows / max(self.n_docs, 1)


def _members_from_assign(assign: np.ndarray, n_clusters: int) -> tuple:
    """Per-cluster member rows, ascending (stable sort of 0..N-1 by
    cluster keeps row order — tie-breaking stays global)."""
    order = np.argsort(assign, kind="stable").astype(np.int32)
    sa = assign[order]
    starts = np.searchsorted(sa, np.arange(n_clusters))
    ends = np.searchsorted(sa, np.arange(n_clusters), side="right")
    return tuple(order[starts[c]: ends[c]] for c in range(n_clusters))


@dataclass(frozen=True)
class IVFIndex:
    """Immutable clustered-index state (see module docstring).

    ``sig_union``/``radius`` are safe upper/lower bounds under
    incremental maintenance: reassignment ORs bits into and lowers the
    radius of the *receiving* cluster; the vacated cluster keeps stale
    (superset/too-low) values until the next train or remap, which only
    makes the exactness bound conservative.
    """

    centroids: np.ndarray   # [kc, D] float32, ℓ2-normalized
    sig_union: np.ndarray   # [kc, W] int32 — OR of member signatures
    radius: np.ndarray      # [kc] float32 — min member·centroid dot
    assign: np.ndarray      # [N] int32 — row → cluster
    members: tuple          # kc × int32 arrays, ascending row indices
    drift: int              # rows that changed cluster since last train
    trained_n: int          # corpus size at last train
    seed: int

    # ---- construction ---------------------------------------------------

    @staticmethod
    def train(doc_vecs, doc_sigs, *, n_clusters: int | None = None,
              seed: int = 0, n_iter: int = 8) -> "IVFIndex":
        """Fit spherical k-means and derive the full index state."""
        cent, assign = spherical_kmeans(doc_vecs, n_clusters,
                                        seed=seed, n_iter=n_iter)
        return IVFIndex.from_assignments(
            cent, assign, doc_vecs, doc_sigs,
            drift=0, trained_n=len(assign), seed=seed,
        )

    @staticmethod
    def from_assignments(centroids, assign, doc_vecs, doc_sigs, *,
                         drift: int, trained_n: int,
                         seed: int) -> "IVFIndex":
        """Exact member/bound recomputation for a given assignment —
        O(N·D); used at train time and on layout restacks (which are
        already O(N) in the engine)."""
        centroids = np.asarray(centroids, np.float32)
        assign = np.asarray(assign, np.int32)
        kc = centroids.shape[0]
        sigs = np.asarray(doc_sigs)
        sig_union = np.zeros((kc, sigs.shape[1] if sigs.ndim == 2 else 0),
                             np.int32)
        radius = np.ones((kc,), np.float32)
        if assign.size:
            np.bitwise_or.at(sig_union, assign, sigs.astype(np.int32))
            dv = np.asarray(doc_vecs, np.float32)
            # analysis: allow[unpinned-reduction] -- cluster radius
            #   bound for pruning; the f64 probe margin absorbs f32
            #   rounding, and the exact rerank guards correctness
            dots = np.einsum("nd,nd->n", dv, centroids[assign])
            np.minimum.at(radius, assign, dots.astype(np.float32))
        return IVFIndex(
            centroids=centroids, sig_union=sig_union, radius=radius,
            assign=assign, members=_members_from_assign(assign, kc),
            drift=int(drift), trained_n=int(trained_n), seed=int(seed),
        )

    # ---- persistence (KnowledgeBase.index_state dict) -------------------

    def state_dict(self, layout_keys) -> dict:
        """The container-facing state: raw arrays + scalars, pinned to
        the doc layout **and content** via ``ids_sha`` (see
        ``ids_digest``; core/ingest.py persists this as ``ivf_*``
        segments + ``meta["index"]``).  ``centroid_sha`` lets the
        persistence plane omit the centroid segment from delta records
        whose chain already carries it (centroids only change on
        retrain — the dominant byte term of an index delta)."""
        return {
            "kind": "ivf",
            "centroids": self.centroids,
            "sig_union": self.sig_union,
            "radius": self.radius,
            "assign": self.assign,
            "drift": int(self.drift),
            "trained_n": int(self.trained_n),
            "seed": int(self.seed),
            "ids_sha": ids_digest(layout_keys),
            "centroid_sha": hashlib.sha256(
                np.ascontiguousarray(self.centroids).tobytes()
            ).hexdigest(),
        }

    @staticmethod
    def from_state(state: dict) -> "IVFIndex":
        """Adopt persisted state verbatim — centroids, assignments and
        bounds are restored bit-identically (no retrain, no bound
        recomputation); only the member lists are rebuilt from the
        assignment array."""
        assign = np.asarray(state["assign"], np.int32)
        centroids = np.asarray(state["centroids"], np.float32)
        return IVFIndex(
            centroids=centroids,
            sig_union=np.asarray(state["sig_union"], np.int32),
            radius=np.asarray(state["radius"], np.float32),
            assign=assign,
            members=_members_from_assign(assign, centroids.shape[0]),
            drift=int(state["drift"]),
            trained_n=int(state["trained_n"]),
            seed=int(state["seed"]),
        )

    # ---- introspection --------------------------------------------------

    @property
    def n_clusters(self) -> int:
        return self.centroids.shape[0]

    @property
    def n_docs(self) -> int:
        return len(self.assign)

    def needs_retrain(self, retrain_drift: float) -> bool:
        """Retrain once membership churn or corpus growth exceeds
        ``retrain_drift`` × the corpus size at the last train."""
        thresh = max(1.0, retrain_drift * max(self.trained_n, 1))
        return (self.drift >= thresh
                or abs(self.n_docs - self.trained_n) >= thresh)

    # ---- incremental maintenance (engine dirty-row log) -----------------

    def reassign(self, rows, row_vecs, row_sigs) -> "IVFIndex":
        """Move changed rows to their nearest centroid — O(U·kc·D).

        ``rows`` index docs whose *content* changed in place (engine
        layout unchanged); ``row_vecs``/``row_sigs`` are those rows
        *already gathered* ([U, D] / [U, W]) so an O(U) refresh never
        pays a full [N, ·] device→host transfer.  The receiving
        cluster's bounds widen (OR the signature, lower the radius);
        the vacated cluster keeps conservative stale bounds.  Returns a
        new index; ``drift`` grows by the number of rows that changed
        cluster.
        """
        rows = np.asarray(rows, np.int32)
        if rows.size == 0:
            return self
        sub = np.asarray(row_vecs, np.float32)
        # analysis: allow[unpinned-reduction] -- incremental reassign
        #   routing; assignment choice never affects served scores
        sims = sub @ self.centroids.T                       # [U, kc]
        new = np.argmax(sims, axis=1).astype(np.int32)
        dots = sims[np.arange(rows.size), new]
        sigs = np.asarray(row_sigs).astype(np.int32)

        assign = self.assign.copy()
        members = list(self.members)
        sig_union = self.sig_union.copy()
        radius = self.radius.copy()
        moved = 0
        for r, c, dot, sg in zip(rows, new, dots, sigs):
            old = assign[r]
            if old != c:
                m = members[old]
                members[old] = m[m != r]
                m = members[c]
                members[c] = np.insert(m, np.searchsorted(m, r), r)
                assign[r] = c
                moved += 1
            sig_union[c] |= sg
            radius[c] = min(radius[c], np.float32(dot))
        return replace(
            self, assign=assign, members=tuple(members),
            sig_union=sig_union, radius=radius, drift=self.drift + moved,
        )

    def remap(self, carried_assign: np.ndarray,
              doc_vecs, doc_sigs) -> "IVFIndex":
        """Rebuild after an engine layout restack (add/remove).

        ``carried_assign`` [new_N] carries each surviving row's old
        cluster; new/changed rows hold −1 and are assigned to their
        nearest centroid here.  Bounds and members are recomputed
        exactly (the restack is already O(N)); drift grows by the
        number of filled rows.
        """
        carried = np.asarray(carried_assign, np.int32).copy()
        fill = np.nonzero(carried < 0)[0]
        if fill.size:
            sub = np.asarray(doc_vecs, np.float32)[fill]
            # analysis: allow[unpinned-reduction] -- remap routing for
            #   compacted rows; routing-only, same argument as reassign
            carried[fill] = np.argmax(
                sub @ self.centroids.T, axis=1
            ).astype(np.int32)
        return IVFIndex.from_assignments(
            self.centroids, carried, doc_vecs, doc_sigs,
            drift=self.drift + int(fill.size),
            trained_n=self.trained_n, seed=self.seed,
        )

    # ---- the two-stage search -------------------------------------------

    def search(self, doc_vecs, doc_sigs, qv: np.ndarray, qs: np.ndarray, *,
               b: int, k: int, nprobe: int, guarantee: str,
               scoring_path: str, alpha: float, beta: float,
               explain: bool = False):
        """Probe + exact rerank → (vals, idx, cos, ind, stats), shaped
        like ``score_batch_arrays`` (idx are *global* doc rows).

        ``qv``/``qs`` may be padded past ``b`` (the engine's
        power-of-two query bucket); only the first ``b`` queries drive
        probing, but all padded rows are scored (their output is
        ignored by ``results_from_topk``).  ``explain=True``
        additionally materializes per-query probe tuples on the stats.
        """
        n, kc = self.n_docs, self.n_clusters
        kk = min(k, n)
        sizes = np.array([m.size for m in self.members], np.int64)
        _t = time.perf_counter() if obs_trace.active() else 0.0

        # -- probe plane (host, float64 for the exactness bound) ----------
        # analysis: allow[unpinned-reduction] -- f64 probe bound, clipped
        #   to [-1,1]; prunes candidates only, exact rerank follows
        a = np.clip(
            qv[:b].astype(np.float64) @ self.centroids.T.astype(np.float64),
            -1.0, 1.0,
        )                                                   # [b, kc]
        qsig = qs[:b].astype(np.int32)
        contain = np.all(
            (self.sig_union[None, :, :] & qsig[:, None, :])
            == qsig[:, None, :], axis=2,
        )                                                   # [b, kc] bool
        if guarantee == "exact":
            cos_ub = exact_cos_upper_bound(a, self.radius)
            ub = alpha * cos_ub + beta * contain            # score bound
            boosted_rank = ub
        else:
            ub = None
            boosted_rank = alpha * a + beta * contain       # optimistic HSF
        # probe order interleaves two rankings: boost-aware (an entity
        # query's target cluster has a tiny centroid cosine but a
        # discriminative signature-union hit) and pure centroid cosine
        # (on big clusters the Bloom union saturates, making `contain`
        # fire broadly — rank-by-boost alone would drown the semantic
        # neighborhoods a topical query needs).  With β = 0 the two
        # rankings coincide.
        order = interleave_probe_order(boosted_rank, a)

        # initial probe width: nprobe, widened until each query's own
        # probed clusters cover ≥ kk docs (so top-k is always full)
        p = np.full((b,), min(max(nprobe, 1), kc), np.int64)
        for i in range(b):
            csum = np.cumsum(sizes[order[i]])
            need = int(np.searchsorted(csum, kk)) + 1
            p[i] = min(max(p[i], need), kc)
        if _t:
            obs_trace.record("ivf_probe", _t, time.perf_counter() - _t,
                             clusters=kc, queries=b,
                             guarantee=guarantee)

        if guarantee == "exact":
            return self._search_exact(doc_vecs, doc_sigs, qv, qs, b=b,
                                      kk=kk, p=p, order=order, ub=ub,
                                      scoring_path=scoring_path,
                                      alpha=alpha, beta=beta,
                                      explain=explain)
        # probe mode: each query scores ONLY its own top-p clusters'
        # rows (one small dispatch per query through the shared gather
        # helper) — a batch of topically diverse queries doesn't
        # inflate each member's scan the way a batch-union gather would
        bp = qv.shape[0]
        vals = np.full((bp, kk), -np.inf, np.float32)
        idx = np.zeros((bp, kk), np.int32)
        cos = np.zeros((bp, kk), np.float32)
        ind = np.zeros((bp, kk), np.float32)
        tot_rows = tot_clusters = 0
        probe_orders, kth = [], []
        _t = time.perf_counter() if obs_trace.active() else 0.0
        for i in range(b):
            probe_c = order[i, : p[i]]
            if p[i] >= kc:
                cand = None  # everything probed: flat row range
                v, gi, cv, iv = score_batch_arrays(
                    doc_vecs, doc_sigs, qv[i: i + 1], qs[i: i + 1],
                    scoring_path=scoring_path, k=kk,
                    alpha=alpha, beta=beta, n_docs=n,
                )
            else:
                cand = np.sort(np.concatenate(
                    [self.members[c] for c in probe_c]
                ))
                v, gi, cv, iv = score_candidate_rows(
                    doc_vecs, doc_sigs, cand, qv[i: i + 1], qs[i: i + 1],
                    scoring_path=scoring_path, k=kk,
                    alpha=alpha, beta=beta,
                )
            vals[i], idx[i], cos[i], ind[i] = v[0], gi[0], cv[0], iv[0]
            tot_rows += n if cand is None else int(cand.size)
            tot_clusters += min(int(p[i]), kc)
            if explain:
                probe_orders.append(
                    tuple(int(c) for c in probe_c[: min(int(p[i]), kc)]))
                kth.append(float(vals[i, kk - 1]))
        if _t:
            obs_trace.record("ivf_rerank", _t, time.perf_counter() - _t,
                             mode="probe", rows=tot_rows, queries=b)
        stats = IVFSearchStats(
            n_docs=n,
            candidate_rows=tot_rows // max(b, 1),   # mean rows scanned
            clusters_probed=tot_clusters // max(b, 1),
            n_clusters=kc,
            rounds=1,
            probe_order=tuple(probe_orders),
            kth_scores=tuple(kth),
            unprobed_bounds=(None,) * b if explain else (),
        )
        return vals, idx, cos, ind, stats

    def _search_exact(self, doc_vecs, doc_sigs, qv, qs, *, b, kk, p,
                      order, ub, scoring_path, alpha, beta,
                      explain=False):
        """Probe-widening rounds over the batch-union candidate set.

        The union gather uses the 2D subset formulation verified
        bit-identical to the flat scan; scoring every query against the
        whole union is a superset per query (recall can only improve)
        and the stop test treats the union as probed for everyone.
        """
        n, kc = self.n_docs, self.n_clusters
        sizes = np.array([m.size for m in self.members], np.int64)
        rounds = 0
        while True:
            rounds += 1
            _tr = time.perf_counter() if obs_trace.active() else 0.0
            probed = np.unique(np.concatenate(
                [order[i, : p[i]] for i in range(b)]
            )) if b else np.arange(kc)
            if probed.size >= kc or sizes[probed].sum() * 2 > n:
                # probe set collapsed to (most of) everything: flat scan
                # — trivially exact, and past ~50% of the rows the full
                # contiguous dispatch beats gathering
                cand = None
                vals, idx, cos, ind = score_batch_arrays(
                    doc_vecs, doc_sigs, qv, qs,
                    scoring_path=scoring_path, k=kk,
                    alpha=alpha, beta=beta, n_docs=n,
                )
            else:
                cand = np.sort(np.concatenate(
                    [self.members[c] for c in probed]
                )) if probed.size else np.zeros((0,), np.int32)
                vals, idx, cos, ind = score_candidate_rows(
                    doc_vecs, doc_sigs, cand, qv, qs,
                    scoring_path=scoring_path, k=kk,
                    alpha=alpha, beta=beta,
                )
            if _tr:
                obs_trace.record(
                    "ivf_widen_round", _tr, time.perf_counter() - _tr,
                    round=rounds,
                    rows=n if cand is None else int(cand.size),
                    clusters=kc if cand is None else int(probed.size))
            if cand is None:
                break
            # stop test: the k-th best exact score must strictly beat
            # every unprobed cluster's bound (ties could displace by
            # doc-index order, so they force another round)
            mask = np.zeros((kc,), bool)
            mask[probed] = True
            done = True
            for i in range(b):
                un = ub[i][~mask]
                if un.size and float(vals[i, kk - 1]) <= un.max():
                    p[i] = min(p[i] * 2, kc)
                    done = False
            if done:
                break
        probe_orders, kth, bounds = [], [], []
        if explain:
            if cand is None:
                mask = np.ones((kc,), bool)   # flat-scan collapse
            else:
                mask = np.zeros((kc,), bool)
                mask[probed] = True
            for i in range(b):
                own = order[i, : min(int(p[i]), kc)]
                probe_orders.append(tuple(int(c) for c in own))
                kth.append(float(vals[i, kk - 1]))
                un = ub[i][~mask]
                bounds.append(float(un.max()) if un.size else None)
        stats = IVFSearchStats(
            n_docs=n,
            candidate_rows=n if cand is None else int(cand.size),
            clusters_probed=kc if cand is None else int(probed.size),
            n_clusters=kc,
            rounds=rounds,
            probe_order=tuple(probe_orders),
            kth_scores=tuple(kth),
            unprobed_bounds=tuple(bounds),
        )
        return vals, idx, cos, ind, stats


# --------------------------------------------------------------------------
# candidate-gather scoring (shared by IVF rerank + the postings prefilter)
# --------------------------------------------------------------------------

@jax.jit
def _gather_rows(doc_vecs, doc_sigs, cand):
    """One fused dispatch for the two row gathers (eager jnp.take pays
    per-op dispatch overhead twice on the per-query hot path)."""
    return (jnp.take(doc_vecs, cand, axis=0),
            jnp.take(doc_sigs, cand, axis=0))


# steady-state retrace accounting (no-op unless RAGDB_SANITIZERS is on);
# kmeans training fns are deliberately unregistered — retrains trace
# new shapes legitimately
sanitizers.register_jit("ivf._gather_rows", _gather_rows)


def score_candidate_rows(doc_vecs, doc_sigs, cand_rows: np.ndarray,
                         qv: np.ndarray, qs: np.ndarray, *,
                         scoring_path: str, k: int,
                         alpha: float, beta: float):
    """Gather a global candidate-row subset and score it exactly.

    ``cand_rows`` must be ascending global row indices — gathered-row
    order then equals global order, so ``lax.top_k``'s tie-breaking
    matches the flat scan, and the returned ``idx`` are mapped back to
    *global* rows.  The subset is padded to a power-of-two row bucket
    (bounded jit recompiles, same trick as the query batch) and scored
    through ``score_batch_arrays`` with ``n_docs`` masking the pad —
    the identical machinery (map / gemm / fused Pallas kernel) the flat
    paths dispatch, which is what makes subset scores bit-identical to
    the corresponding rows of the full scan.
    """
    n = int(len(cand_rows))
    kk = min(k, n)
    candp = np.zeros((_bucket(n),), np.int32)
    candp[:n] = cand_rows
    sub_vecs, sub_sigs = _gather_rows(doc_vecs, doc_sigs,
                                      jnp.asarray(candp))
    vals, idx, cos, ind = score_batch_arrays(
        sub_vecs, sub_sigs, qv, qs, scoring_path=scoring_path, k=kk,
        alpha=alpha, beta=beta, n_docs=n,
    )
    return vals, candp[idx], cos, ind
